"""Co-run application layer: AppLoad protocol, the concrete loads, the
Runtime/Server wiring, and the demand -> interference contention
mapping (the paper's Sec 5.6 CPU-sharing scenario)."""

import time

import numpy as np
import pytest

from repro.core import MetronomeConfig
from repro.runtime import (
    AppLoad,
    BoundedQueue,
    BusyPollPolicy,
    DutyCycleBurner,
    MatmulAppLoad,
    MetronomePolicy,
    PoissonWorkload,
    Runtime,
    RunStats,
    SimRunConfig,
    co_run_config,
    simulate_run,
)


def _policy(m=2):
    return MetronomePolicy(MetronomeConfig(m=m, v_target_us=500.0,
                                           t_long_us=5_000.0))


def test_loads_satisfy_protocol():
    assert isinstance(DutyCycleBurner(0.3), AppLoad)
    assert isinstance(MatmulAppLoad(n=32), AppLoad)
    assert DutyCycleBurner(0.3, threads=2).threads == 2
    assert DutyCycleBurner(0.3).demand == pytest.approx(0.3)
    with pytest.raises(ValueError):
        DutyCycleBurner(-0.1)


def test_duty_cycle_burner_burns_its_share():
    app = DutyCycleBurner(demand=0.5, period_us=2_000.0)
    app.reset()
    t0 = time.perf_counter_ns()
    for _ in range(3):
        assert app.step() == 1
    wall_us = (time.perf_counter_ns() - t0) / 1e3
    # 3 quanta of a 2ms period: at least the burn phases, and not
    # wildly more than the full periods (generous CI-scheduler slack)
    assert wall_us >= 3 * 0.5 * 2_000.0 * 0.8
    assert wall_us <= 3 * 2_000.0 * 10


def test_runtime_co_runs_app_load_and_reports_progress():
    q = [BoundedQueue(1024)]
    rt = Runtime(q, process=lambda items: None, policy=_policy(),
                 app_load=DutyCycleBurner(demand=0.5, period_us=1_000.0))
    rt.start()
    for i in range(100):
        q[0].push(i)
    time.sleep(0.25)
    st = rt.stop()
    assert st.items == 100
    assert st.app_ops > 0
    assert st.app_cpu_ns > 0
    assert 0.0 < st.app_cpu_fraction
    assert rt._app_threads == []           # joined and cleared
    # the I/O task's CPU accounting excludes the app's burn
    assert st.awake_ns + st.app_cpu_ns <= 2 * st.duration_ns


def test_matmul_app_load_steps_on_jax():
    app = MatmulAppLoad(n=32)
    app.reset()
    assert app.step() == 1
    assert app.step() == 1


def test_server_app_load_passthrough():
    from repro.serving import Server

    class _NullEngine:
        def submit(self, reqs):
            pass

        def pump(self):
            return False

    srv = Server(_NullEngine(), _policy(),
                 app_load=DutyCycleBurner(demand=0.4, period_us=1_000.0))
    srv.start()
    time.sleep(0.2)
    st = srv.stop()
    assert st.app_ops > 0
    assert st.app_cpu_ns > 0


def test_run_stats_merge_adds_app_counters():
    a = RunStats(app_ops=3, app_cpu_ns=1_000)
    b = RunStats(app_ops=5, app_cpu_ns=2_500)
    a.merge(b)
    assert a.app_ops == 8
    assert a.app_cpu_ns == 3_500


# ---------------------------------------------------------------------------
# demand -> SimRunConfig contention mapping
# ---------------------------------------------------------------------------

def test_co_run_config_zero_demand_is_identity():
    cfg = SimRunConfig()
    assert co_run_config(cfg, 0.0) is cfg
    assert co_run_config(cfg, 0.0, spin=True) is cfg
    with pytest.raises(ValueError):
        co_run_config(cfg, -0.5)


def test_co_run_config_sleepwake_mapping():
    cfg = SimRunConfig()
    c = co_run_config(cfg, 0.6, preempt_mean_us=8.0,
                      pileup_every_us=8_000.0, pileup_mean_us=120.0)
    assert c.interference_prob == pytest.approx(0.6)
    assert c.interference_mean_us == pytest.approx(8.0)
    assert c.stall_rate_per_us == pytest.approx(0.6 / 8_000.0)
    assert c.stall_mean_us == pytest.approx(120.0)
    # demand saturates at one core
    assert co_run_config(cfg, 2.0).interference_prob == pytest.approx(1.0)


def test_co_run_config_spin_mapping_caps_at_fair_share():
    cfg = SimRunConfig()
    c = co_run_config(cfg, 0.3, spin=True, quantum_us=250.0)
    assert c.stall_rate_per_us == pytest.approx(0.3 / 250.0)
    assert c.stall_mean_us == pytest.approx(250.0)
    assert c.interference_prob == 0.0      # a spinner has no wakes
    # against an always-runnable spinner the app's share caps at 1/2
    c_hi = co_run_config(cfg, 0.9, spin=True, quantum_us=250.0)
    assert c_hi.stall_rate_per_us == pytest.approx(0.5 / 250.0)


def test_co_run_config_layers_on_existing_interference():
    base = SimRunConfig(interference_prob=0.2, interference_mean_us=10.0,
                        stall_rate_per_us=1e-4, stall_mean_us=50.0)
    c = co_run_config(base, 0.5, preempt_mean_us=8.0,
                      pileup_every_us=10_000.0, pileup_mean_us=100.0)
    # Bernoulli union, expected-delay-preserving mean
    assert c.interference_prob == pytest.approx(1 - 0.8 * 0.5)
    exp_delay = 0.2 * 10.0 + 0.5 * 8.0
    assert (c.interference_prob * c.interference_mean_us
            == pytest.approx(exp_delay))
    assert c.stall_rate_per_us == pytest.approx(1e-4 + 0.5 / 10_000.0)
    # stall means combine weighted by rate contribution
    assert (c.stall_rate_per_us * c.stall_mean_us
            == pytest.approx(1e-4 * 50.0 + 0.5 / 10_000.0 * 100.0))


def test_co_run_simulation_shows_the_sharing_asymmetry():
    """The headline: under a co-run app, sleep&wake keeps near-zero loss
    while the descheduled spinner starts dropping — the simulation-side
    counterpart of benchmarks/cpu_sharing.py's verdict."""
    cfg = SimRunConfig(duration_us=40_000.0, queue_capacity=4096)
    wl = lambda: PoissonWorkload(0.45 * 29.76)  # noqa: E731
    d = 0.6
    rs_m = simulate_run(_paper_metronome(), wl(), co_run_config(cfg, d))
    rs_b = simulate_run(BusyPollPolicy(), wl(),
                        co_run_config(cfg, d, spin=True))
    rs_b0 = simulate_run(BusyPollPolicy(), wl(), cfg)
    assert rs_m.loss_fraction < 1e-3
    assert rs_b.loss_fraction > 0.01
    assert rs_b.mean_latency_us > 20 * max(rs_b0.mean_latency_us, 1e-9)
    assert np.isfinite(rs_m.p99_latency_us)


def _paper_metronome():
    return MetronomePolicy(MetronomeConfig())
