"""Tests for the unified repro.runtime API: policy/workload protocols,
sim/real parity, trace-replay math, bounded stats, deprecation shims."""

import time
import warnings

import numpy as np
import pytest

from repro.core import MetronomeConfig
from repro.runtime import (
    BoundedQueue,
    BusyPollPolicy,
    CBRWorkload,
    EqualTimeoutsPolicy,
    FixedPeriodPolicy,
    MetronomePolicy,
    OnOffBurstyWorkload,
    PoissonWorkload,
    Reservoir,
    RetrievalPolicy,
    Runtime,
    SimRunConfig,
    TraceReplayWorkload,
    Workload,
    simulate_run,
)
from repro.core.hr_sleep import naive_sleep


# ---------------------------------------------------------------------------
# protocols
# ---------------------------------------------------------------------------

def test_policies_and_workloads_satisfy_protocols():
    policies = [BusyPollPolicy(), MetronomePolicy(),
                FixedPeriodPolicy(50.0), EqualTimeoutsPolicy()]
    workloads = [PoissonWorkload(1.0), CBRWorkload(1.0),
                 OnOffBurstyWorkload(4.0),
                 TraceReplayWorkload([0.0, 1.0, 2.0])]
    for p in policies:
        assert isinstance(p, RetrievalPolicy), p
    for w in workloads:
        assert isinstance(w, Workload), w


def test_every_policy_runs_against_every_workload_in_sim():
    """The acceptance grid: 4 policies x 4 workloads, one engine."""
    trace = np.cumsum(np.full(50_000, 0.5))          # 2 Mpps CBR-ish trace
    mk_workloads = [
        lambda: PoissonWorkload(2.0),
        lambda: CBRWorkload(2.0),
        lambda: OnOffBurstyWorkload(8.0, on_mean_us=2_000.0,
                                    off_mean_us=6_000.0),
        lambda: TraceReplayWorkload(trace, speedup=2.0, jitter=0.1, loop=True),
    ]
    mk_policies = [
        lambda: BusyPollPolicy(),
        lambda: MetronomePolicy(MetronomeConfig(m=3)),
        lambda: FixedPeriodPolicy(50.0),
        lambda: EqualTimeoutsPolicy(MetronomeConfig(m=3, v_target_us=10.0)),
    ]
    for mw in mk_workloads:
        for mp in mk_policies:
            p, w = mp(), mw()
            rs = simulate_run(p, w, SimRunConfig(duration_us=20_000.0, seed=1))
            assert rs.serviced > 0, (p, w)
            assert rs.offered >= rs.serviced
            assert 0.0 < rs.cpu_fraction <= max(p.threads, 1) + 0.1
            if getattr(p, "spin", False):
                assert rs.cpu_fraction == pytest.approx(1.0)


def test_policy_instance_reusable_across_backends():
    """The same policy object runs in the simulator, then on real threads."""
    policy = MetronomePolicy(MetronomeConfig(m=2, v_target_us=500.0,
                                             t_long_us=5_000.0))
    rs_sim = simulate_run(policy, PoissonWorkload(1.0),
                          SimRunConfig(duration_us=50_000.0, seed=2))
    assert rs_sim.serviced > 0

    q = BoundedQueue(4096)
    seen = []
    rt = Runtime([q], process=seen.extend, policy=policy)
    rt.start()
    for i in range(50):
        q.push(i)
        time.sleep(0.001)
    time.sleep(0.05)
    rs_real = rt.stop()
    assert sorted(seen) == list(range(50))
    assert rs_real.items == 50
    assert rs_real.cpu_fraction < 1.0


# ---------------------------------------------------------------------------
# sim/real parity
# ---------------------------------------------------------------------------

def _spin_us(us: float) -> None:
    end = time.perf_counter_ns() + int(us * 1_000)
    while time.perf_counter_ns() < end:
        pass


def _parity_pair(rate_per_us: float, service_us: float, duration_us: float,
                 seed: int = 3):
    """Run the same policy config under the same Poisson workload in the
    simulator and on real threads; return (sim_stats, real_stats, policies)."""
    def mk_policy():
        return MetronomePolicy(MetronomeConfig(m=2, v_target_us=1_000.0,
                                               t_long_us=20_000.0))

    p_sim = mk_policy()
    rs_sim = simulate_run(
        p_sim, PoissonWorkload(rate_per_us),
        SimRunConfig(duration_us=duration_us,
                     service_rate_mpps=1.0 / service_us, seed=seed))

    p_real = mk_policy()

    def process(items):
        for _ in items:
            _spin_us(service_us)

    rt = Runtime([BoundedQueue(65_536)], process=process, policy=p_real,
                 sleep_fn=naive_sleep)
    rs_real = rt.run(PoissonWorkload(rate_per_us), duration_us=duration_us,
                     seed=seed)
    return rs_sim, rs_real, p_sim, p_real


@pytest.mark.slow
def test_sim_real_parity_metronome_poisson():
    """The same MetronomePolicy configuration converges to similar rho /
    T_S and the same CPU-fraction trend in the discrete-event simulator
    and on real threads (loose bands: the real backend rides a noisy
    shared host)."""
    lo = _parity_pair(rate_per_us=0.001, service_us=100.0,
                      duration_us=1_200_000.0)
    hi = _parity_pair(rate_per_us=0.004, service_us=100.0,
                      duration_us=1_200_000.0)

    for rs_sim, rs_real, p_sim, p_real in (lo, hi):
        assert rs_real.items > 0 and rs_sim.items > 0
        # rho estimates land in the same band (true rho: 0.1 / 0.4)
        assert abs(p_sim.rho - p_real.rho) < 0.25, (p_sim.rho, p_real.rho)
        # adaptive T_S within a small factor of each other
        ratio = p_sim.t_short_us / p_real.t_short_us
        assert 0.4 < ratio < 2.5, (p_sim.t_short_us, p_real.t_short_us)
        # both backends sleep most of the time at these loads
        assert rs_sim.cpu_fraction < 0.9
        assert rs_real.cpu_fraction < 0.9

    # trend parity: 4x the load raises rho in both backends.  The real
    # backend's EWMA rides empty-win cycles (a second primary waking just
    # after a busy period drags B/(B+V) toward 0), so its margin is looser.
    assert hi[2].rho > lo[2].rho + 0.1          # sim
    assert hi[3].rho > lo[3].rho + 0.04         # real
    # and raises CPU in both backends
    assert hi[0].cpu_fraction > lo[0].cpu_fraction
    assert hi[1].cpu_fraction > lo[1].cpu_fraction


# ---------------------------------------------------------------------------
# trace replay math
# ---------------------------------------------------------------------------

def test_trace_replay_speedup_exact_without_jitter():
    ts = [100.0, 300.0, 500.0, 900.0]
    wl = TraceReplayWorkload(ts, speedup=2.0, jitter=0.0)
    wl.reset(np.random.default_rng(0))
    np.testing.assert_allclose(wl._times, [0.0, 100.0, 200.0, 400.0])
    assert wl.counts_in(0.0, 150.0) == 2          # arrivals at 0 and 100
    assert wl.counts_in(150.0, 400.0) == 1        # arrival at 200 ([t0, t1))
    assert wl.counts_in(400.0, 1e9) == 1          # arrival at 400
    # mean rate scales with speedup: 4 pkts over (900-100)/2 us
    assert wl.mean_rate_mpps == pytest.approx(4 / 400.0)


def test_trace_replay_jitter_bounds_and_determinism():
    ts = np.cumsum(np.full(2_000, 10.0))
    wl = TraceReplayWorkload(ts, speedup=1.0, jitter=0.25)
    wl.reset(np.random.default_rng(7))
    gaps = np.diff(wl._times)
    assert gaps.min() >= 10.0 * 0.75 - 1e-9
    assert gaps.max() <= 10.0 * 1.25 + 1e-9
    assert gaps.std() > 0.1                        # jitter actually applied
    # unbiased in expectation
    assert np.mean(gaps) == pytest.approx(10.0, rel=0.05)
    # same seed -> same replay; different seed -> different replay
    wl2 = TraceReplayWorkload(ts, speedup=1.0, jitter=0.25)
    wl2.reset(np.random.default_rng(7))
    np.testing.assert_array_equal(wl._times, wl2._times)
    wl3 = TraceReplayWorkload(ts, speedup=1.0, jitter=0.25)
    wl3.reset(np.random.default_rng(8))
    assert not np.array_equal(wl._times, wl3._times)


def test_trace_replay_loop_extends_monotonically():
    wl = TraceReplayWorkload([0.0, 10.0, 20.0], jitter=0.0, loop=True)
    wl.reset(np.random.default_rng(0))
    n = wl.counts_in(0.0, 200.0)
    assert n > 3                                   # looped past one lap
    assert np.all(np.diff(wl._times) >= 0)
    arr = list(wl.iter_arrivals(95.0, np.random.default_rng(0)))
    assert arr == sorted(arr)
    assert all(t < 95.0 for t in arr)


def test_trace_replay_validation():
    with pytest.raises(ValueError):
        TraceReplayWorkload([])
    with pytest.raises(ValueError):
        TraceReplayWorkload([1.0], speedup=0.0)
    with pytest.raises(ValueError):
        TraceReplayWorkload([1.0], jitter=1.5)


# ---------------------------------------------------------------------------
# workload accounting
# ---------------------------------------------------------------------------

def test_cbr_counts_are_deterministic_and_exact():
    wl = CBRWorkload(0.5)                          # one packet every 2us
    wl.reset(np.random.default_rng(0))
    total = sum(wl.counts_in(t, t + 7.0) for t in np.arange(0.0, 700.0, 7.0))
    assert total == 350
    assert wl.counts_in(10.0, 10.0) == 0


def test_onoff_counts_match_duty_cycle():
    wl = OnOffBurstyWorkload(10.0, on_mean_us=1_000.0, off_mean_us=3_000.0)
    wl.reset(np.random.default_rng(11))
    dur = 2_000_000.0
    total = sum(wl.counts_in(t, t + 50.0) for t in np.arange(0.0, dur, 50.0))
    expected = 10.0 * wl.duty_cycle * dur
    assert total == pytest.approx(expected, rel=0.2)


# ---------------------------------------------------------------------------
# bounded stats
# ---------------------------------------------------------------------------

def test_reservoir_is_bounded_and_uniform_ish():
    r = Reservoir(capacity=1_000, seed=0)
    r.extend(float(i) for i in range(100_000))
    assert len(r) == 1_000
    assert r.count == 100_000
    med = float(np.median(r))
    assert 30_000 < med < 70_000                   # uniform sample, not a head
    assert np.median(np.asarray(r)) == med         # numpy interop


def test_runtime_restart_does_not_double_count():
    """Queue/lock counters are cumulative; a restarted Runtime must report
    only its own run's arrivals."""
    q = BoundedQueue(4096)
    rt = Runtime([q], process=lambda b: None,
                 policy=FixedPeriodPolicy(200.0, threads=1))
    for _ in range(2):
        rt.start()
        for i in range(100):
            q.push(i)
        deadline = time.monotonic() + 5.0
        while len(q) and time.monotonic() < deadline:
            time.sleep(0.005)
        st = rt.stop()
        assert st.offered == 100
        assert st.items == 100
        assert st.dropped == 0


def test_runtime_latency_samples_bounded():
    q = BoundedQueue(100_000)
    rt = Runtime([q], process=lambda b: None,
                 policy=FixedPeriodPolicy(200.0, threads=1),
                 latency_sample_every=1, latency_reservoir=256)
    rt.start()
    for i in range(3_000):
        q.push(i)
    deadline = time.monotonic() + 5.0
    while len(q) and time.monotonic() < deadline:
        time.sleep(0.01)
    st = rt.stop()
    assert st.items == 3_000
    assert len(st.latency_samples_us) <= 256       # capped despite the flood


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------

def test_core_shims_still_resolve_and_warn():
    from repro.core import (
        BoundedQueue as BQ,
        BusyPollLoop,
        MetronomePollers,
        PollerStats,
        SimConfig,
        simulate,
    )
    from repro.runtime import RunStats

    assert BQ is BoundedQueue
    assert PollerStats is RunStats

    with pytest.warns(DeprecationWarning):
        mp = MetronomePollers([BoundedQueue(16)], process=lambda b: None)
    assert isinstance(mp, Runtime)
    assert mp.controller is mp.policy.controller
    with pytest.warns(DeprecationWarning):
        bp = BusyPollLoop([BoundedQueue(16)], process=lambda b: None)
    assert isinstance(bp.policy, BusyPollPolicy)

    res = simulate(SimConfig(duration_us=20_000.0, seed=5))
    assert res.serviced > 0


def test_serving_shims_still_resolve_and_warn():
    from repro.serving import BusyPollServer, MetronomeServer, Server, ServerStats
    from repro.runtime import RunStats

    assert ServerStats is RunStats
    assert issubclass(MetronomeServer, Server)
    assert issubclass(BusyPollServer, Server)

    class _NullEngine:
        def submit(self, reqs):
            pass

        def pump(self):
            return False

    with pytest.warns(DeprecationWarning):
        srv = MetronomeServer(_NullEngine())
    assert isinstance(srv.policy, MetronomePolicy)
    assert srv.controller is srv.policy.controller
    with pytest.warns(DeprecationWarning):
        bsrv = BusyPollServer(_NullEngine())
    assert isinstance(bsrv.policy, BusyPollPolicy)


def test_old_import_surface_unchanged():
    """Everything the old repro.core exported still imports cleanly."""
    import repro.core as core

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        for name in core.__all__:
            assert getattr(core, name) is not None, name
