"""Tests for the unified repro.runtime API: policy/workload protocols,
multi-queue dispatch/assignment, sim/real parity, trace-replay math,
bounded stats, deprecation shims."""

import time
import warnings

import numpy as np
import pytest

from repro.core import MetronomeConfig
from repro.runtime import (
    BoundedQueue,
    BusyPollPolicy,
    CBRWorkload,
    DedicatedAssignment,
    Dispatcher,
    EqualTimeoutsPolicy,
    FixedPeriodPolicy,
    FlowHashDispatch,
    LeastLoadedDispatch,
    MetronomePolicy,
    OnOffBurstyWorkload,
    PoissonWorkload,
    Reservoir,
    RetrievalPolicy,
    RoundRobinDispatch,
    RunStats,
    Runtime,
    SharedAssignment,
    SimRunConfig,
    StealingAssignment,
    TraceReplayWorkload,
    Workload,
    simulate_run,
)
from repro.core.hr_sleep import naive_sleep


# ---------------------------------------------------------------------------
# protocols
# ---------------------------------------------------------------------------

def test_policies_and_workloads_satisfy_protocols():
    policies = [BusyPollPolicy(), MetronomePolicy(),
                FixedPeriodPolicy(50.0), EqualTimeoutsPolicy()]
    workloads = [PoissonWorkload(1.0), CBRWorkload(1.0),
                 OnOffBurstyWorkload(4.0),
                 TraceReplayWorkload([0.0, 1.0, 2.0])]
    for p in policies:
        assert isinstance(p, RetrievalPolicy), p
    for w in workloads:
        assert isinstance(w, Workload), w


def test_every_policy_runs_against_every_workload_in_sim():
    """The acceptance grid: 4 policies x 4 workloads, one engine."""
    trace = np.cumsum(np.full(50_000, 0.5))          # 2 Mpps CBR-ish trace
    mk_workloads = [
        lambda: PoissonWorkload(2.0),
        lambda: CBRWorkload(2.0),
        lambda: OnOffBurstyWorkload(8.0, on_mean_us=2_000.0,
                                    off_mean_us=6_000.0),
        lambda: TraceReplayWorkload(trace, speedup=2.0, jitter=0.1, loop=True),
    ]
    mk_policies = [
        lambda: BusyPollPolicy(),
        lambda: MetronomePolicy(MetronomeConfig(m=3)),
        lambda: FixedPeriodPolicy(50.0),
        lambda: EqualTimeoutsPolicy(MetronomeConfig(m=3, v_target_us=10.0)),
    ]
    for mw in mk_workloads:
        for mp in mk_policies:
            p, w = mp(), mw()
            rs = simulate_run(p, w, SimRunConfig(duration_us=20_000.0, seed=1))
            assert rs.serviced > 0, (p, w)
            assert rs.offered >= rs.serviced
            assert 0.0 < rs.cpu_fraction <= max(p.threads, 1) + 0.1
            if getattr(p, "spin", False):
                assert rs.cpu_fraction == pytest.approx(1.0)


def test_policy_instance_reusable_across_backends():
    """The same policy object runs in the simulator, then on real threads."""
    policy = MetronomePolicy(MetronomeConfig(m=2, v_target_us=500.0,
                                             t_long_us=5_000.0))
    rs_sim = simulate_run(policy, PoissonWorkload(1.0),
                          SimRunConfig(duration_us=50_000.0, seed=2))
    assert rs_sim.serviced > 0

    q = BoundedQueue(4096)
    seen = []
    rt = Runtime([q], process=seen.extend, policy=policy)
    rt.start()
    for i in range(50):
        q.push(i)
        time.sleep(0.001)
    time.sleep(0.05)
    rs_real = rt.stop()
    assert sorted(seen) == list(range(50))
    assert rs_real.items == 50
    assert rs_real.cpu_fraction < 1.0


# ---------------------------------------------------------------------------
# multi-queue ingress: dispatchers, assignments, conservation
# ---------------------------------------------------------------------------

# Pinned pre-refactor outputs: simulate_run with n_queues=1 and the default
# round-robin dispatcher must reproduce the original single-queue event
# sequence bit for bit (same seed => same wakeups/cycles/drops/vacations).
# awake_ns values pin round()-based us->ns conversion (not truncation).
_SINGLE_QUEUE_GOLDENS = [
    (
        lambda: MetronomePolicy(MetronomeConfig(m=3, v_target_us=10.0,
                                                t_long_us=500.0)),
        lambda: PoissonWorkload(14.88),
        lambda: SimRunConfig(duration_us=200_000.0, seed=7),
        dict(wakeups=6031, cycles=5276, busy_tries=755, serviced=2975499,
             offered=2975499, dropped=0, awake_ns=106014165,
             mean_vac=18.95650064499486, mean_busy=18.950562039912935),
    ),
    (
        lambda: FixedPeriodPolicy(50.0, threads=2),
        lambda: OnOffBurstyWorkload(20.0, on_mean_us=2_000.0,
                                    off_mean_us=5_000.0),
        lambda: SimRunConfig(duration_us=150_000.0, seed=11,
                             queue_capacity=512),
        dict(wakeups=4764, cycles=4069, busy_tries=695, serviced=1196066,
             offered=1308145, dropped=112079, awake_ns=44954390,
             mean_vac=26.98560342251278, mean_busy=9.877215479220014),
    ),
    (
        lambda: EqualTimeoutsPolicy(MetronomeConfig(m=3, v_target_us=10.0)),
        lambda: PoissonWorkload(2.0),
        lambda: SimRunConfig(duration_us=100_000.0, seed=3,
                             interference_prob=0.05,
                             interference_mean_us=50.0,
                             stall_rate_per_us=0.0001, stall_mean_us=100.0),
        dict(wakeups=18231, cycles=13610, busy_tries=4621, serviced=200139,
             offered=200139, dropped=0, awake_ns=24956101,
             mean_vac=6.85276468234786, mean_busy=0.49412937593325584),
    ),
]


@pytest.mark.parametrize("case", range(len(_SINGLE_QUEUE_GOLDENS)))
def test_single_queue_reduction_is_exact(case):
    mk_p, mk_w, mk_c, gold = _SINGLE_QUEUE_GOLDENS[case]
    rs = simulate_run(mk_p(), mk_w(), mk_c(), dispatcher=RoundRobinDispatch())
    assert rs.wakeups == gold["wakeups"]
    assert rs.cycles == gold["cycles"]
    assert rs.busy_tries == gold["busy_tries"]
    assert rs.items == gold["serviced"]
    assert rs.offered == gold["offered"]
    assert rs.dropped == gold["dropped"]
    assert rs.awake_ns == gold["awake_ns"]
    assert float(np.mean(rs.vacations_us)) == pytest.approx(
        gold["mean_vac"], rel=1e-12)
    assert float(np.mean(rs.busies_us)) == pytest.approx(
        gold["mean_busy"], rel=1e-12)


def _assert_per_queue_conserves(rs, n_queues):
    assert len(rs.per_queue) == n_queues
    assert sum(q.offered for q in rs.per_queue) == rs.offered
    assert sum(q.dropped for q in rs.per_queue) == rs.dropped
    assert sum(q.serviced for q in rs.per_queue) == rs.items
    assert sum(q.busy_tries for q in rs.per_queue) == rs.busy_tries


@pytest.mark.parametrize("mk_dispatch", [
    RoundRobinDispatch, FlowHashDispatch, LeastLoadedDispatch])
@pytest.mark.parametrize("mk_assign", [
    SharedAssignment, DedicatedAssignment, StealingAssignment])
def test_sim_per_queue_conservation(mk_dispatch, mk_assign):
    policy = MetronomePolicy(MetronomeConfig(m=4, v_target_us=10.0,
                                             t_long_us=500.0))
    rs = simulate_run(policy, PoissonWorkload(10.0),
                      SimRunConfig(duration_us=30_000.0, seed=5, n_queues=4),
                      dispatcher=mk_dispatch(), assignment=mk_assign())
    assert rs.items > 0
    _assert_per_queue_conserves(rs, 4)


@pytest.mark.parametrize("mk_assign", [
    SharedAssignment, DedicatedAssignment, StealingAssignment])
def test_threads_per_queue_conservation(mk_assign):
    qs = [BoundedQueue(4096) for _ in range(3)]
    seen = []
    rt = Runtime(qs, process=seen.extend,
                 policy=MetronomePolicy(MetronomeConfig(
                     m=3, v_target_us=500.0, t_long_us=5_000.0)),
                 assignment=mk_assign())
    rt.start()
    for i in range(300):
        qs[i % 3].push(i)
        if i % 50 == 0:
            time.sleep(0.002)
    deadline = time.monotonic() + 5.0
    while any(len(q) for q in qs) and time.monotonic() < deadline:
        time.sleep(0.005)
    st = rt.stop()
    assert sorted(seen) == list(range(300))
    _assert_per_queue_conserves(st, 3)


@pytest.mark.parametrize("mk_dispatch", [
    RoundRobinDispatch, FlowHashDispatch, LeastLoadedDispatch])
def test_dispatch_split_sums_and_respects_pick_range(mk_dispatch):
    d = mk_dispatch()
    assert isinstance(d, Dispatcher)
    rng = np.random.default_rng(0)
    d.reset(5, rng)
    backlogs = np.array([3.0, 0.0, 10.0, 1.0, 7.0])
    for n in (0, 1, 7, 1234):
        parts = d.split(n, backlogs)
        assert parts.sum() == n
        assert parts.min() >= 0
        assert len(parts) == 5
    for seq in range(50):
        assert 0 <= d.pick(seq, backlogs) < 5


def test_flow_hash_dispatch_affinity_and_skew():
    d = FlowHashDispatch(n_flows=32, zipf_s=1.5)
    d.reset(4, np.random.default_rng(3))
    # same key always lands in the same queue
    for key in ("sess-a", 17, ("user", 4)):
        picks = {d.pick(i, [0, 0, 0, 0], key=key) for i in range(10)}
        assert len(picks) == 1
    # Zipf weights are genuinely skewed: top queue well above fair share
    w = d.queue_weights
    assert w.sum() == pytest.approx(1.0)
    assert w.max() > 1.5 / 4


def test_least_loaded_dispatch_water_fills():
    d = LeastLoadedDispatch()
    d.reset(3, np.random.default_rng(0))
    parts = d.split(6, np.array([10.0, 0.0, 2.0]))
    # all 6 go to the two shortest queues, leveling them below the longest
    assert parts[0] == 0
    assert parts.sum() == 6
    assert parts[1] >= parts[2]
    assert d.pick(0, [5, 1, 3]) == 1


def test_sim_threads_parity_multi_queue_skewed():
    """The same MetronomePolicy config under the same Zipf-skewed Poisson
    load runs on both backends with 3 queues: per-queue accounting
    conserves on both, and the skew shows up in the same ordering."""
    def mk_policy():
        return MetronomePolicy(MetronomeConfig(m=3, v_target_us=1_000.0,
                                               t_long_us=20_000.0))

    rs_sim = simulate_run(
        mk_policy(), PoissonWorkload(0.002),
        SimRunConfig(duration_us=300_000.0, service_rate_mpps=0.02,
                     seed=13, n_queues=3),
        dispatcher=FlowHashDispatch(n_flows=16, zipf_s=2.0),
        assignment=StealingAssignment())
    _assert_per_queue_conserves(rs_sim, 3)
    assert rs_sim.items > 0

    qs = [BoundedQueue(65_536) for _ in range(3)]
    rt = Runtime(qs, process=lambda b: None, policy=mk_policy(),
                 sleep_fn=naive_sleep, assignment=StealingAssignment())
    rs_real = rt.run(PoissonWorkload(0.002), duration_us=300_000.0, seed=13,
                     dispatcher=FlowHashDispatch(n_flows=16, zipf_s=2.0))
    _assert_per_queue_conserves(rs_real, 3)
    assert rs_real.items > 0
    # both backends drew the same flow->queue table (same seed), so the
    # busiest queue index agrees between sim and threads
    busiest_sim = max(rs_sim.per_queue, key=lambda q: q.offered).queue
    busiest_real = max(rs_real.per_queue, key=lambda q: q.offered).queue
    assert busiest_sim == busiest_real
    # and both backends sleep most of the time at this light load
    assert rs_sim.cpu_fraction < 0.9
    assert rs_real.cpu_fraction < 0.9


def test_dedicated_assignment_clones_controllers():
    policy = MetronomePolicy(MetronomeConfig(m=2, v_target_us=10.0))
    slots = DedicatedAssignment().slots(policy, 3)
    assert len(slots) == 6                       # 2 threads x 3 queues
    pols = {id(s.policy) for s in slots}
    assert len(pols) == 3                        # one clone per queue
    assert all(id(s.policy) != id(policy) for s in slots)
    # single queue: no cloning, caller's policy object stays observable
    slots1 = DedicatedAssignment().slots(policy, 1)
    assert all(s.policy is policy for s in slots1)


def test_stealing_demotes_only_redundant_home_pollers():
    """A ring's sole home poller keeps its primary cadence on a missed
    trylock; only redundant homes take the paper's backup role."""
    policy = FixedPeriodPolicy(50.0, threads=5)
    slots = StealingAssignment().slots(policy, 4)
    assert [s.queues[0] for s in slots] == [0, 1, 2, 3, 0]
    # queue 0 has two home pollers -> they demote; queues 1-3 do not
    assert [s.demote_on_miss for s in slots] == [True, False, False, False,
                                                 True]
    assert all(s.steal for s in slots)


def test_drain_truncation_counted_and_warned():
    """A saturated run (offered rate > service rate) hits the 64-round
    drain cap; the truncation must be counted, not silently eaten."""
    rs = simulate_run(
        FixedPeriodPolicy(20.0, threads=1), PoissonWorkload(2.0),
        SimRunConfig(duration_us=30_000.0, service_rate_mpps=1.0,
                     queue_capacity=100_000, seed=0))
    assert rs.drain_truncations > 0
    with pytest.warns(RuntimeWarning, match="drain round cap"):
        s = rs.summary()
    assert s["drain_truncations"] == rs.drain_truncations


def test_runtime_rearms_vacation_clock_on_start():
    """BoundedQueue stamps last_busy_end_ns at construction; a Runtime
    started later must not report the queue's pre-start age as the first
    vacation."""
    q = BoundedQueue(64)
    vacs = []

    class Recording(FixedPeriodPolicy):
        def on_cycle_end(self, busy_us, vacation_us):
            vacs.append(vacation_us)

    rt = Runtime([q], process=lambda b: None,
                 policy=Recording(200.0, threads=1))
    time.sleep(0.25)                 # queue ages before the runtime starts
    rt.start()
    q.push(1)
    time.sleep(0.05)
    rt.stop()
    assert vacs, "no cycle observed"
    assert vacs[0] < 200_000         # << the 250ms pre-start age


# ---------------------------------------------------------------------------
# sim/real parity
# ---------------------------------------------------------------------------

def _spin_us(us: float) -> None:
    end = time.perf_counter_ns() + int(us * 1_000)
    while time.perf_counter_ns() < end:
        pass


def _parity_pair(rate_per_us: float, service_us: float, duration_us: float,
                 seed: int = 3):
    """Run the same policy config under the same Poisson workload in the
    simulator and on real threads; return (sim_stats, real_stats, policies)."""
    def mk_policy():
        return MetronomePolicy(MetronomeConfig(m=2, v_target_us=1_000.0,
                                               t_long_us=20_000.0))

    p_sim = mk_policy()
    rs_sim = simulate_run(
        p_sim, PoissonWorkload(rate_per_us),
        SimRunConfig(duration_us=duration_us,
                     service_rate_mpps=1.0 / service_us, seed=seed))

    p_real = mk_policy()

    def process(items):
        for _ in items:
            _spin_us(service_us)

    rt = Runtime([BoundedQueue(65_536)], process=process, policy=p_real,
                 sleep_fn=naive_sleep)
    rs_real = rt.run(PoissonWorkload(rate_per_us), duration_us=duration_us,
                     seed=seed)
    return rs_sim, rs_real, p_sim, p_real


@pytest.mark.slow
def test_sim_real_parity_metronome_poisson():
    """The same MetronomePolicy configuration converges to similar rho /
    T_S and the same CPU-fraction trend in the discrete-event simulator
    and on real threads (loose bands: the real backend rides a noisy
    shared host; one retry absorbs scheduling-noise outliers)."""
    for attempt in range(2):
        try:
            _check_parity_metronome_poisson()
            return
        except AssertionError:
            if attempt == 1:
                raise


def _check_parity_metronome_poisson():
    lo = _parity_pair(rate_per_us=0.001, service_us=100.0,
                      duration_us=1_200_000.0)
    hi = _parity_pair(rate_per_us=0.004, service_us=100.0,
                      duration_us=1_200_000.0)

    for rs_sim, rs_real, p_sim, p_real in (lo, hi):
        assert rs_real.items > 0 and rs_sim.items > 0
        # rho estimates land in the same band (true rho: 0.1 / 0.4)
        assert abs(p_sim.rho - p_real.rho) < 0.25, (p_sim.rho, p_real.rho)
        # adaptive T_S within a small factor of each other
        ratio = p_sim.t_short_us / p_real.t_short_us
        assert 0.4 < ratio < 2.5, (p_sim.t_short_us, p_real.t_short_us)
        # both backends sleep most of the time at these loads
        assert rs_sim.cpu_fraction < 0.9
        assert rs_real.cpu_fraction < 0.9

    # trend parity: 4x the load raises rho in both backends.  The real
    # backend's EWMA rides empty-win cycles (a second primary waking just
    # after a busy period drags B/(B+V) toward 0) plus host scheduling
    # noise, so it only gets a directional margin (gaps of +0.03 with the
    # old 0.04 margin were observed flaking on busy hosts).
    assert hi[2].rho > lo[2].rho + 0.1          # sim
    assert hi[3].rho > lo[3].rho + 0.01         # real
    # and raises CPU in both backends
    assert hi[0].cpu_fraction > lo[0].cpu_fraction
    assert hi[1].cpu_fraction > lo[1].cpu_fraction


# ---------------------------------------------------------------------------
# trace replay math
# ---------------------------------------------------------------------------

def test_trace_replay_speedup_exact_without_jitter():
    ts = [100.0, 300.0, 500.0, 900.0]
    wl = TraceReplayWorkload(ts, speedup=2.0, jitter=0.0)
    wl.reset(np.random.default_rng(0))
    np.testing.assert_allclose(wl._times, [0.0, 100.0, 200.0, 400.0])
    assert wl.counts_in(0.0, 150.0) == 2          # arrivals at 0 and 100
    assert wl.counts_in(150.0, 400.0) == 1        # arrival at 200 ([t0, t1))
    assert wl.counts_in(400.0, 1e9) == 1          # arrival at 400
    # mean rate scales with speedup: 4 pkts over (900-100)/2 us
    assert wl.mean_rate_mpps == pytest.approx(4 / 400.0)


def test_trace_replay_jitter_bounds_and_determinism():
    ts = np.cumsum(np.full(2_000, 10.0))
    wl = TraceReplayWorkload(ts, speedup=1.0, jitter=0.25)
    wl.reset(np.random.default_rng(7))
    gaps = np.diff(wl._times)
    assert gaps.min() >= 10.0 * 0.75 - 1e-9
    assert gaps.max() <= 10.0 * 1.25 + 1e-9
    assert gaps.std() > 0.1                        # jitter actually applied
    # unbiased in expectation
    assert np.mean(gaps) == pytest.approx(10.0, rel=0.05)
    # same seed -> same replay; different seed -> different replay
    wl2 = TraceReplayWorkload(ts, speedup=1.0, jitter=0.25)
    wl2.reset(np.random.default_rng(7))
    np.testing.assert_array_equal(wl._times, wl2._times)
    wl3 = TraceReplayWorkload(ts, speedup=1.0, jitter=0.25)
    wl3.reset(np.random.default_rng(8))
    assert not np.array_equal(wl._times, wl3._times)


def test_trace_replay_loop_extends_monotonically():
    wl = TraceReplayWorkload([0.0, 10.0, 20.0], jitter=0.0, loop=True)
    wl.reset(np.random.default_rng(0))
    n = wl.counts_in(0.0, 200.0)
    assert n > 3                                   # looped past one lap
    assert np.all(np.diff(wl._times) >= 0)
    arr = list(wl.iter_arrivals(95.0, np.random.default_rng(0)))
    assert arr == sorted(arr)
    assert all(t < 95.0 for t in arr)


def test_trace_replay_validation():
    with pytest.raises(ValueError):
        TraceReplayWorkload([])
    with pytest.raises(ValueError):
        TraceReplayWorkload([1.0], speedup=0.0)
    with pytest.raises(ValueError):
        TraceReplayWorkload([1.0], jitter=1.5)
    # zero-span looped trace would never advance a lap: rejected upfront
    with pytest.raises(ValueError, match="nonzero span"):
        TraceReplayWorkload([5.0, 5.0], loop=True)
    # single-timestamp looped trace still terminates (floored restart gap)
    wl = TraceReplayWorkload([5.0], loop=True)
    wl.reset(np.random.default_rng(0))
    assert wl.counts_in(0.0, 1.0) >= 1


# ---------------------------------------------------------------------------
# workload accounting
# ---------------------------------------------------------------------------

def test_cbr_counts_are_deterministic_and_exact():
    wl = CBRWorkload(0.5)                          # one packet every 2us
    wl.reset(np.random.default_rng(0))
    total = sum(wl.counts_in(t, t + 7.0) for t in np.arange(0.0, 700.0, 7.0))
    assert total == 350
    assert wl.counts_in(10.0, 10.0) == 0


def test_onoff_counts_match_duty_cycle():
    wl = OnOffBurstyWorkload(10.0, on_mean_us=1_000.0, off_mean_us=3_000.0)
    wl.reset(np.random.default_rng(11))
    dur = 2_000_000.0
    total = sum(wl.counts_in(t, t + 50.0) for t in np.arange(0.0, dur, 50.0))
    expected = 10.0 * wl.duty_cycle * dur
    assert total == pytest.approx(expected, rel=0.2)


# ---------------------------------------------------------------------------
# bounded stats
# ---------------------------------------------------------------------------

def test_reservoir_is_bounded_and_uniform_ish():
    r = Reservoir(capacity=1_000, seed=0)
    r.extend(float(i) for i in range(100_000))
    assert len(r) == 1_000
    assert r.count == 100_000
    med = float(np.median(r))
    assert 30_000 < med < 70_000                   # uniform sample, not a head
    assert np.median(np.asarray(r)) == med         # numpy interop


def test_reservoir_vectorized_extend_matches_algorithm_r():
    """Array-like inputs take the bulk numpy path; the Algorithm-R
    invariant (bounded, uniform over everything seen) must survive."""
    r = Reservoir(capacity=1_000, seed=1)
    r.extend(np.arange(0, 40_000, dtype=np.float64))        # ndarray
    r.extend(list(range(40_000, 80_000)))                   # list
    r.extend(float(x) for x in range(80_000, 100_000))      # generator tail
    assert len(r) == 1_000
    assert r.count == 100_000
    med = float(np.median(r))
    assert 30_000 < med < 70_000
    # mixed-path chunk sizes seen in the simulator (tiny lists) still work
    r2 = Reservoir(capacity=8, seed=2)
    for i in range(100):
        r2.extend([float(i)] * 3)
    assert len(r2) == 8
    assert r2.count == 300
    # empty batches are a no-op
    r2.extend([])
    r2.extend(np.empty(0))
    assert r2.count == 300


def test_reservoir_merge_lossless_then_weighted():
    """merge() is exact concatenation while both sides are lossless and
    a count-weighted union (still bounded, still uniform-ish) after."""
    a = Reservoir(capacity=100, seed=0)
    b = Reservoir(capacity=100, seed=1)
    a.extend([1.0, 2.0, 3.0])
    b.extend([4.0, 5.0])
    a.merge(b)
    assert sorted(a) == [1.0, 2.0, 3.0, 4.0, 5.0]
    assert a.count == 5
    # weighted regime: one side saw 9x the data; the merged sample's
    # composition must reflect the 9:1 stream weights, not the 1:1
    # buffer sizes
    big = Reservoir(capacity=500, seed=2)
    small = Reservoir(capacity=500, seed=3)
    big.extend(np.zeros(45_000))
    small.extend(np.ones(5_000))
    big.merge(small)
    assert len(big) == 500
    assert big.count == 50_000
    ones = float(np.sum(np.asarray(big)))
    assert 20 <= ones <= 90                       # ~10% +- sampling noise
    # merging an empty reservoir is a no-op
    before = list(big)
    big.merge(Reservoir(capacity=10, seed=4))
    assert list(big) == before


def test_run_stats_merge_combines_shards():
    """Two equal-window sim shards merge into one run: counters add,
    per-queue slices add by index, reservoirs pool, and cpu_fraction
    becomes total cores burned over the shared window."""
    def run(seed):
        return simulate_run(
            MetronomePolicy(MetronomeConfig(m=3, v_target_us=10.0,
                                            t_long_us=500.0)),
            PoissonWorkload(10.0),
            SimRunConfig(duration_us=30_000.0, seed=seed, n_queues=2))

    a, b, fresh_a = run(1), run(2), run(1)
    merged = a.merge(b)
    assert merged is a
    for f in ("wakeups", "cycles", "busy_tries", "items", "offered",
              "dropped", "awake_ns"):
        assert getattr(merged, f) == getattr(fresh_a, f) + getattr(b, f), f
    assert merged.duration_ns == fresh_a.duration_ns      # same window
    assert merged.cpu_fraction == pytest.approx(
        fresh_a.cpu_fraction + b.cpu_fraction, rel=1e-9)
    _assert_per_queue_conserves(merged, 2)
    assert merged.latency_us.count == (fresh_a.latency_us.count
                                       + b.latency_us.count)
    lo = min(fresh_a.mean_latency_us, b.mean_latency_us)
    hi = max(fresh_a.mean_latency_us, b.mean_latency_us)
    assert lo - 1e-9 <= merged.mean_latency_us <= hi + 1e-9
    # Little-law integrals add too
    assert merged.latency_area_us == pytest.approx(
        fresh_a.latency_area_us + b.latency_area_us)
    assert merged.vacations_us.size == (fresh_a.vacations_us.size
                                        + b.vacations_us.size)
    # same-policy labels survive; mixed ones collapse
    assert merged.policy == fresh_a.policy
    c = run(3)
    c.policy = "other"
    merged.merge(c)
    assert merged.policy == "mixed"


def test_run_stats_merge_single_queue_no_reservoir_double_count():
    """Regression: with n_queues=1 the run-level and per-queue[0]
    reservoirs must not alias — merge() pools run-level and per-queue
    independently, and aliasing double-counted the donor's samples
    (count came out A + 2B)."""
    def run(seed):
        return simulate_run(
            MetronomePolicy(MetronomeConfig(m=2, v_target_us=10.0,
                                            t_long_us=500.0)),
            PoissonWorkload(8.0),
            SimRunConfig(duration_us=20_000.0, seed=seed))

    a, b, fresh_a = run(1), run(2), run(1)
    assert a.latency_us is not a.per_queue[0].latency_us
    b_count = b.latency_us.count
    b_buf = list(b.latency_us)
    a.merge(b)
    assert a.latency_us.count == fresh_a.latency_us.count + b_count
    assert a.per_queue[0].latency_us.count == a.latency_us.count
    # the donor is untouched by the merge...
    assert b.latency_us.count == b_count
    assert list(b.latency_us) == b_buf
    # ...even after the adopting side merges again (no adopted aliases)
    empty = RunStats(backend="sim", policy=a.policy, workload=a.workload)
    empty.merge(b)
    b_q0 = b.per_queue[0]
    before = (b_q0.offered, b_q0.serviced, b_q0.latency_us.count)
    empty.merge(run(3))
    assert (b_q0.offered, b_q0.serviced,
            b_q0.latency_us.count) == before


def test_per_queue_reservoirs_decorrelated_and_merge_to_total():
    """Each queue carries its own latency reservoir (decorrelated
    seeds), and the run-level reservoir is their weighted union."""
    rs = simulate_run(
        MetronomePolicy(MetronomeConfig(m=4, v_target_us=10.0,
                                        t_long_us=500.0)),
        PoissonWorkload(12.0),
        SimRunConfig(duration_us=40_000.0, seed=5, n_queues=4))
    per_q = [q.latency_us for q in rs.per_queue]
    assert all(r is not None for r in per_q)
    assert sum(r.count for r in per_q) == rs.latency_us.count
    # distinct eviction rngs: spawned seeds differ across queues
    states = {id(r._np_rng) for r in per_q}
    assert len(states) == 4
    seeds_differ = {r._rng.random() for r in per_q}
    assert len(seeds_differ) == 4


def test_runtime_restart_does_not_double_count():
    """Queue/lock counters are cumulative; a restarted Runtime must report
    only its own run's arrivals."""
    q = BoundedQueue(4096)
    rt = Runtime([q], process=lambda b: None,
                 policy=FixedPeriodPolicy(200.0, threads=1))
    for _ in range(2):
        rt.start()
        for i in range(100):
            q.push(i)
        deadline = time.monotonic() + 5.0
        while len(q) and time.monotonic() < deadline:
            time.sleep(0.005)
        st = rt.stop()
        assert st.offered == 100
        assert st.items == 100
        assert st.dropped == 0


def test_runtime_latency_samples_bounded():
    q = BoundedQueue(100_000)
    rt = Runtime([q], process=lambda b: None,
                 policy=FixedPeriodPolicy(200.0, threads=1),
                 latency_sample_every=1, latency_reservoir=256)
    rt.start()
    for i in range(3_000):
        q.push(i)
    deadline = time.monotonic() + 5.0
    while len(q) and time.monotonic() < deadline:
        time.sleep(0.01)
    st = rt.stop()
    assert st.items == 3_000
    assert len(st.latency_samples_us) <= 256       # capped despite the flood


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------

def test_core_shims_still_resolve_and_warn():
    from repro.core import (
        BoundedQueue as BQ,
        BusyPollLoop,
        MetronomePollers,
        PollerStats,
        SimConfig,
        simulate,
    )
    from repro.runtime import RunStats

    assert BQ is BoundedQueue
    assert PollerStats is RunStats

    with pytest.warns(DeprecationWarning):
        mp = MetronomePollers([BoundedQueue(16)], process=lambda b: None)
    assert isinstance(mp, Runtime)
    assert mp.controller is mp.policy.controller
    with pytest.warns(DeprecationWarning):
        bp = BusyPollLoop([BoundedQueue(16)], process=lambda b: None)
    assert isinstance(bp.policy, BusyPollPolicy)

    res = simulate(SimConfig(duration_us=20_000.0, seed=5))
    assert res.serviced > 0


def test_serving_shims_still_resolve_and_warn():
    from repro.serving import BusyPollServer, MetronomeServer, Server, ServerStats
    from repro.runtime import RunStats

    assert ServerStats is RunStats
    assert issubclass(MetronomeServer, Server)
    assert issubclass(BusyPollServer, Server)

    class _NullEngine:
        def submit(self, reqs):
            pass

        def pump(self):
            return False

    with pytest.warns(DeprecationWarning):
        srv = MetronomeServer(_NullEngine())
    assert isinstance(srv.policy, MetronomePolicy)
    assert srv.controller is srv.policy.controller
    with pytest.warns(DeprecationWarning):
        bsrv = BusyPollServer(_NullEngine())
    assert isinstance(bsrv.policy, BusyPollPolicy)


def test_old_import_surface_unchanged():
    """Everything the old repro.core exported still imports cleanly."""
    import repro.core as core

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        for name in core.__all__:
            assert getattr(core, name) is not None, name


# ---------------------------------------------------------------------------
# dynamic concurrency sanitizer over real threaded runs (CI: -k threaded)
# ---------------------------------------------------------------------------

def _metronome_policy():
    return MetronomePolicy(MetronomeConfig(m=2, v_target_us=500.0,
                                           t_long_us=5_000.0))


def test_threaded_runtime_sanitizer_confirms_no_races():
    """The tier-1 race gate: a real instrumented Runtime run with the
    Eraser state machine watching every queue/stats attribute access
    must end with zero confirmed races, and the traced locks must have
    recorded real hold-time telemetry."""
    from repro.analysis.sanitizer import Sanitizer

    q = BoundedQueue(4096)
    seen = []
    rt = Runtime([q], process=seen.extend, policy=_metronome_policy())
    with Sanitizer() as san:
        san.instrument_runtime(rt)
        rt.start()
        for i in range(50):
            q.push(i)
            time.sleep(0.001)
        time.sleep(0.05)
        rs = rt.stop()
    assert rs.items == 50 and sorted(seen) == list(range(50))
    assert san.confirmed_races() == []
    locks = san.lock_report()
    assert locks["_stats_lock"]["acquisitions"] > 0
    assert locks["queue.lock"]["acquisitions"] > 0
    assert sum(locks["_stats_lock"]["hold_ns_hist"].values()) > 0


def test_threaded_server_sanitizer_confirms_no_races():
    """Same gate through the serving layer: sharded ingress, the engine
    lock's blocking/try-acquire split, and the runtime underneath."""
    from repro.analysis.sanitizer import Sanitizer
    from repro.serving import Server

    class _NullEngine:
        def submit(self, reqs):
            pass

        def pump(self):
            return False

    srv = Server(_NullEngine(), _metronome_policy(), n_queues=2)
    with Sanitizer() as san:
        san.instrument_server(srv)
        srv.start()
        for i in range(30):
            srv.submit([i])
            time.sleep(0.001)
        time.sleep(0.05)
        srv.stop()
    assert san.confirmed_races() == []
    locks = san.lock_report()
    assert {"_engine_lock", "_submit_lock", "_stats_lock",
            "queue.lock"} <= set(locks)


def test_threaded_sanitizer_catches_seeded_race():
    """The gate must be able to fail: an intentionally unguarded
    two-thread counter bump is reported, and validate() maps a static
    finding quoting the same class/attribute to CONFIRMED."""
    import threading

    from repro.analysis.sanitizer import Sanitizer

    class Buggy:
        def __init__(self):
            self.hits = 0

        def worker(self):
            for _ in range(20_000):
                self.hits += 1

    b = Buggy()
    with Sanitizer() as san:
        san.trace(b)
        ts = [threading.Thread(target=b.worker) for _ in range(2)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
    races = san.confirmed_races()
    assert [(r["class"], r["attr"]) for r in races] == [("Buggy", "hits")]

    static = [{"rule": "RACE002", "fingerprint": "x", "path": "p",
               "message": ("unsynchronized read-modify-write of "
                           "'self.hits' in 'worker': no lock held, "
                           "concurrent threads can lose updates")}]
    (verdict,) = san.validate(static)
    assert verdict["status"] == "CONFIRMED"


def test_threaded_sanitizer_validates_static_fixture_findings(tmp_path):
    """PLAUSIBLE -> UNOBSERVED plumbing: the static RACE findings from
    the fixture suite stay UNOBSERVED against a clean run, and the
    saved JSON report carries races + lock histograms + verdicts."""
    import json as _json
    from pathlib import Path

    from repro.analysis import run_analysis
    from repro.analysis.sanitizer import Sanitizer

    repo = Path(__file__).resolve().parents[1]
    fixtures = repo / "tests" / "analysis_fixtures"
    static = run_analysis(
        [fixtures / "race_write_bad.py", fixtures / "race_rmw_bad.py"],
        root=repo).findings
    assert static, "fixture findings expected"

    q = BoundedQueue(1024)
    rt = Runtime([q], process=lambda b: None, policy=_metronome_policy())
    with Sanitizer() as san:
        san.instrument_runtime(rt)
        rt.start()
        for i in range(10):
            q.push(i)
            time.sleep(0.001)
        rt.stop()
    report_path = tmp_path / "sanitizer_report.json"
    san.save(report_path, static)
    payload = _json.loads(report_path.read_text())
    assert payload["schema"] == "repro-sanitizer/1"
    assert payload["races"] == []
    assert {v["status"] for v in payload["validated"]} == {"UNOBSERVED"}
    assert payload["locks"]["queue.lock"]["acquisitions"] > 0


def test_threaded_sanitizer_uninstrument_restores_classes():
    """Tracing patches type(obj); leaving the context must restore the
    class so later tests see pristine Runtime/queue behavior."""
    from repro.analysis.sanitizer import Sanitizer

    orig_set = BoundedQueue.__setattr__
    orig_get = BoundedQueue.__getattribute__
    q = BoundedQueue(16)
    with Sanitizer() as san:
        san.trace(q)
        assert BoundedQueue.__setattr__ is not orig_set
        q.push(1)
    assert BoundedQueue.__setattr__ is orig_set
    assert BoundedQueue.__getattribute__ is orig_get
