"""Simulator vs analytics cross-validation — the paper's Fig 5 / Sec 5 logic."""

import numpy as np
import pytest

from repro.core import analytics as an
from repro.core.simulator import (
    HR_SLEEP_MODEL,
    NANOSLEEP_MODEL,
    PERFECT_SLEEP_MODEL,
    SimConfig,
    simulate,
    simulate_busy_poll,
)


def _base(**kw):
    d = dict(duration_us=300_000.0, seed=7)
    d.update(kw)
    return SimConfig(**d)


def test_decorrelation_pdf_matches_eq9():
    """Paper Fig 5: empirical vacation PDF ~= analytic Eq (9), T_L = T_S.

    Run at line rate: the paper's own justification for decorrelation is
    that "each service time, due to its random duration, de-synchronizes"
    the threads — with negligible traffic there are no service times and
    thread phases can lock (we verified the simulator shows exactly that
    synchronized regime at lambda ~ 0).
    """
    ts = 50.0
    m = 3
    cfg = _base(adaptive=False, equal_timeouts=True, v_target_us=ts,
                sleep_model=HR_SLEEP_MODEL, m=m,
                arrival_rate_mpps=14.88, duration_us=900_000.0)
    res = simulate(cfg)
    v = res.vacations_us
    v = v[(v > 0) & (v < ts)]
    assert v.size > 2000
    hist, edges = np.histogram(v, bins=20, range=(0, ts), density=True)
    centers = 0.5 * (edges[:-1] + edges[1:])
    pdf = an.vacation_pdf_high(centers, ts, ts, m)     # Eq (9), T_L = T_S
    err = np.abs(hist - pdf) / pdf.max()
    assert np.median(err) < 0.2


def test_mean_vacation_low_load():
    """At rho->0 all threads stay primary: E[V] ~ T_S/M (Eq 8)."""
    ts = 30.0
    cfg = _base(adaptive=False, v_target_us=ts, m=3,
                arrival_rate_mpps=0.001, sleep_model=PERFECT_SLEEP_MODEL)
    res = simulate(cfg)
    assert res.mean_vacation_us == pytest.approx(an.mean_vacation_low(ts, 3), rel=0.1)


def test_mean_vacation_high_load():
    """At high load: one primary + M-1 backups -> Eq (6)."""
    ts, tl, m = 10.0, 500.0, 3
    cfg = _base(adaptive=False, v_target_us=ts, t_long_us=tl, m=m,
                arrival_rate_mpps=14.88, service_rate_mpps=29.76,
                sleep_model=PERFECT_SLEEP_MODEL)
    res = simulate(cfg)
    assert res.mean_vacation_us == pytest.approx(
        an.mean_vacation_high(ts, tl, m), rel=0.15)


def test_rho_estimate_tracks_true_load():
    cfg = _base(adaptive=True, arrival_rate_mpps=14.88, service_rate_mpps=29.76,
                timeseries_bin_us=50_000.0)
    res = simulate(cfg)
    true_rho = 14.88 / 29.76
    assert res.rho_series[-1] == pytest.approx(true_rho, abs=0.08)


def test_adaptive_targets_constant_vacation():
    """Eq (12) keeps E[V] *flat across loads* near the target.

    The paper's own Table 2 measures V ~= 2x the target (19.55us @ 10us) —
    the idealized Eq (13) model misses sleep overshoot and role churn
    (collisions knock threads into T_L sleeps), and our simulator
    reproduces that measured 2x factor.  So we assert what the mechanism
    actually provides: the measured mean vacation stays within a narrow
    band (<= 2.5x target, like the paper's measurements) while the offered
    load varies 14x, instead of scaling with T_S (which varies 3x over the
    same range).
    """
    means = []
    for lam in (1.0, 7.0, 14.0):
        cfg = _base(adaptive=True, v_target_us=10.0, arrival_rate_mpps=lam,
                    service_rate_mpps=29.76, sleep_model=HR_SLEEP_MODEL)
        res = simulate(cfg)
        means.append(res.mean_vacation_us)
        assert 0.8 * 10.0 <= res.mean_vacation_us <= 2.5 * 10.0, (lam, means)
    assert max(means) / min(means) < 1.6, means


def test_no_loss_at_paper_operating_point():
    """Paper Table 2: V-bar=10us, 1024 descriptors, line rate -> ~0 loss."""
    cfg = _base(adaptive=True, v_target_us=10.0, arrival_rate_mpps=14.88,
                service_rate_mpps=29.76, sleep_model=HR_SLEEP_MODEL)
    res = simulate(cfg)
    assert res.loss_fraction < 1e-4
    assert res.serviced > 0.99 * res.offered * (1 - res.loss_fraction)


def test_nanosleep_causes_loss_at_line_rate():
    """Paper Table 3: same config on nanosleep loses packets (~6% in paper)."""
    cfg = _base(adaptive=True, v_target_us=10.0, arrival_rate_mpps=14.88,
                service_rate_mpps=29.76, sleep_model=NANOSLEEP_MODEL)
    res = simulate(cfg)
    assert res.loss_fraction > 0.005


def test_loss_grows_with_vacation_target():
    """Paper Table 2 trend: larger V-bar -> larger backlog N_V, more loss."""
    losses, nvs = [], []
    for v in (5.0, 10.0, 20.0, 40.0):
        cfg = _base(adaptive=True, v_target_us=v, arrival_rate_mpps=14.88,
                    service_rate_mpps=29.76, queue_capacity=1024)
        r = simulate(cfg)
        losses.append(r.loss_fraction)
        nvs.append(r.mean_nv)
    # N_V grows with the target until the queue capacity clamps it.
    uncapped = [n for n in nvs if n < 0.9 * 1024]
    assert uncapped == sorted(uncapped) and len(uncapped) >= 3
    assert losses[-1] > losses[0]
    assert losses[0] < 1e-3                     # small target: (near) no loss


def test_cpu_scales_with_load_and_beats_busy_poll():
    """Paper Fig 12b: CPU ~ load; busy-poll is pinned at 100%."""
    fracs = []
    for lam in (0.5, 7.0, 14.0):
        cfg = _base(adaptive=True, arrival_rate_mpps=lam, service_rate_mpps=29.76)
        fracs.append(simulate(cfg).cpu_fraction)
    assert fracs == sorted(fracs)
    assert fracs[-1] < 1.0                       # < one full core even at line rate
    bp = simulate_busy_poll(_base(arrival_rate_mpps=14.0))
    assert bp.cpu_fraction == 1.0
    assert fracs[0] < 0.35 * bp.cpu_fraction


def test_equal_timeouts_waste_cpu_at_high_load():
    """Paper Fig 7 motivation: T_L=T_S burns wakeups on busy tries."""
    eq = simulate(_base(equal_timeouts=True, adaptive=False, v_target_us=10.0,
                        arrival_rate_mpps=14.88, service_rate_mpps=29.76))
    dv = simulate(_base(equal_timeouts=False, adaptive=False, v_target_us=10.0,
                        arrival_rate_mpps=14.88, service_rate_mpps=29.76))
    assert eq.busy_tries > 3 * max(dv.busy_tries, 1)


def test_busy_tries_fall_with_longer_tl():
    """Paper Fig 7: busy tries decrease monotonically with T_L."""
    tries = []
    for tl in (100.0, 300.0, 500.0, 700.0):
        cfg = _base(adaptive=False, t_long_us=tl, arrival_rate_mpps=14.88,
                    service_rate_mpps=29.76)
        tries.append(simulate(cfg).busy_tries)
    assert tries == sorted(tries, reverse=True)


def test_multithread_resilience_to_interference():
    """Paper Sec 5.6: under OS interference, M=3 loses less than M=1."""
    kw = dict(adaptive=True, arrival_rate_mpps=14.88, service_rate_mpps=29.76,
              interference_prob=0.3, interference_mean_us=300.0,
              queue_capacity=512, duration_us=400_000.0)
    one = simulate(_base(m=1, **kw))
    three = simulate(_base(m=3, **kw))
    assert three.loss_fraction < one.loss_fraction


def test_uncorrelated_tails_absorbed_but_correlated_stalls_are_not():
    """The Table-3 modeling discovery: backup threads absorb uncorrelated
    per-thread delay tails (bounded loss growth with queue size), while
    correlated system-wide stalls overflow even a 4x larger ring — the
    paper's nanosleep failure mode (Sec 3.1)."""
    import dataclasses
    base = dict(adaptive=True, v_target_us=10.0, arrival_rate_mpps=14.88,
                service_rate_mpps=29.76, duration_us=800_000.0)
    tails = dataclasses.replace(HR_SLEEP_MODEL, tail_prob=0.01,
                                tail_mean_us=400.0)
    # uncorrelated tails: big ring nearly eliminates loss
    small_u = simulate(_base(sleep_model=tails, queue_capacity=1024, **base))
    big_u = simulate(_base(sleep_model=tails, queue_capacity=4096, **base))
    assert big_u.loss_fraction < 0.25 * max(small_u.loss_fraction, 1e-9) \
        or big_u.loss_fraction < 1e-4
    # correlated stalls: 4x ring barely helps
    small_c = simulate(_base(sleep_model=HR_SLEEP_MODEL, queue_capacity=1024,
                             stall_rate_per_us=3.5e-5, stall_mean_us=1200.0,
                             **base))
    big_c = simulate(_base(sleep_model=HR_SLEEP_MODEL, queue_capacity=4096,
                           stall_rate_per_us=3.5e-5, stall_mean_us=1200.0,
                           **base))
    assert big_c.loss_fraction > 0.3 * small_c.loss_fraction
    assert big_c.loss_fraction > 0.005


def test_adaptation_tracks_time_varying_load():
    """Paper Fig 11: rho and T_S follow a ramp-up/ramp-down profile."""
    peak = 14.0
    dur = 600_000.0

    def profile(t):
        x = t / dur
        return peak * (2 * x if x < 0.5 else 2 * (1 - x))

    cfg = _base(adaptive=True, arrival_profile=profile, duration_us=dur,
                service_rate_mpps=29.76, timeseries_bin_us=20_000.0)
    res = simulate(cfg)
    mid = len(res.rho_series) // 2
    # rho climbs into the peak and falls after it; T_S does the opposite.
    assert res.rho_series[mid] > res.rho_series[2] + 0.1
    assert res.rho_series[mid] > res.rho_series[-2] + 0.1
    assert res.ts_series[2] > res.ts_series[mid]
    # throughput tracks offered load (no systematic loss)
    assert res.serviced > 0.98 * (res.offered - res.dropped)


def test_paper_config_operating_point():
    """The paper's own Sec-5 configuration (configs/metronome_l3fwd.py)
    must hit its published operating point: no loss at line rate, CPU well
    below one core."""
    import dataclasses

    from repro.configs.metronome_l3fwd import PAPER_SIM

    res = simulate(dataclasses.replace(PAPER_SIM, duration_us=400_000.0,
                                       seed=11))
    assert res.loss_fraction < 1e-4
    assert res.cpu_fraction < 0.75
    assert 10.0 <= res.mean_vacation_us <= 25.0   # paper measured 19.55
