"""Energy accounting across every execution layer.

The model (simcore.EnergyModel) is charged identically by the event
engine, both batched kernels (fixed-slot + adaptive event-jump), and
the fleet engine, so an *exact* conservation identity is testable on
each:

    energy_uj == active_power_w * awake_us
               + ts_arms     * arm_energy(T_S)
               + busy_tries  * arm_energy(T_L)

plus: windowed energy sums (and the event engine's post-duration
spill) reproduce the totals, merge/merge_all conserve cluster energy,
and the engines agree with each other within pinned bands on the same
config family the latency/CPU parity tests use.

Checked two ways, mirroring tests/test_stepping.py: seeded-random
sweeps that always run, and the same properties under hypothesis when
it is installed.
"""

from dataclasses import replace

import numpy as np
import pytest

from repro.core import MetronomeConfig
from repro.core.hr_sleep import calibrate
from repro.runtime import (
    DEEP_CSTATE_ENERGY_MODEL,
    DEFAULT_ENERGY_MODEL,
    BusyPollPolicy,
    EnergyModel,
    MetronomePolicy,
    PoissonWorkload,
    SimRunConfig,
    SweepGrid,
    simulate_batch,
    simulate_run,
)
from repro.runtime.simcore import HR_SLEEP_MODEL, WindowAccum

STEPPINGS = ("fixed", "adaptive")

# Same f32-accumulator rationale as test_stepping.CONS_REL: the
# identity must hold far tighter than any physical effect, not bit-exact
CONS_REL = 2e-3

# Cross-engine energy parity bands, pinned on the same config family as
# the latency/CPU bands in test_batched_engine.py (n_queues=1,
# HR_SLEEP_MODEL, 120 ms).  Measured gap on that family is ~1%; the
# band leaves the same headroom ratio the latency bands do.
E_REL, E_ABS_UJ = 0.08, 50.0
EPP_REL, EPP_ABS_NJ = 0.08, 2.0


def _band_points(n=3, seed=11):
    """Operating points inside the pinned parity-band family."""
    rng = np.random.default_rng(seed)
    pts = []
    for i in range(n):
        t_s = float(rng.uniform(5.0, 40.0))
        pts.append(dict(
            t_s_us=t_s,
            t_l_us=float(t_s * rng.uniform(4.0, 25.0)),
            m=int(rng.integers(1, 5)),
            n_queues=1,
            rate_mpps=float(rng.uniform(0.15, 0.85) * 29.76),
            seed=500 + i))
    return pts


def _mixed_points(n=8, seed=4):
    """Wider family (multi-queue too) for the conservation identity,
    which must hold at ANY operating point, not just the parity band."""
    rng = np.random.default_rng(seed)
    pts = []
    for i in range(n):
        t_s = float(rng.uniform(5.0, 50.0))
        pts.append(dict(
            t_s_us=t_s,
            t_l_us=float(t_s * rng.uniform(4.0, 20.0)),
            m=int(rng.integers(1, 5)),
            n_queues=int(rng.integers(1, 4)),
            rate_mpps=float(rng.uniform(0.1, 0.8) * 29.76),
            seed=2000 + i))
    return pts


def _event_run(p, cfg):
    pol = MetronomePolicy(
        MetronomeConfig(m=p["m"], v_target_us=p["t_s_us"],
                        t_long_us=p["t_l_us"],
                        ts_min_us=min(1.0, p["t_s_us"])),
        adaptive=False)
    return simulate_run(pol, PoissonWorkload(p["rate_mpps"]), cfg)


def _check_conservation(bs, em):
    """The exact identity on a BatchStats, via public counters only."""
    arm_s = np.array([em.arm_energy_uj(t) for t in np.asarray(bs.grid.t_s_us)])
    arm_l = np.array([em.arm_energy_uj(t) for t in np.asarray(bs.grid.t_l_us)])
    pred = (em.active_power_w * bs.awake_us
            + bs.ts_arms * arm_s + bs.busy_tries * arm_l)
    np.testing.assert_allclose(bs.energy_uj, pred, rtol=CONS_REL, atol=1.0)
    if bs.win.size:
        np.testing.assert_allclose(bs.win[:, :, 4].sum(axis=1), bs.energy_uj,
                                   rtol=CONS_REL, atol=1.0)
    assert np.all(bs.energy_uj > 0.0)
    assert np.all(bs.energy_per_packet_nj > 0.0)
    assert np.all(bs.mean_power_w > 0.0)


# ------------------------------------------------------------ the model

def test_energy_model_state_selection_and_costs():
    em = EnergyModel(active_power_w=8.0,
                     sleep_states=((1.0, 0.5, 0.0),
                                   (0.4, 4.0, 30.0),
                                   (0.1, 20.0, 300.0)),
                     dvfs_busy_scale=1.5)
    # deepest state whose residency floor fits the programmed target
    assert em.select(10.0) == (1.0, 0.5)
    assert em.select(30.0) == (0.4, 4.0)
    assert em.select(299.9) == (0.4, 4.0)
    assert em.select(1000.0) == (0.1, 20.0)
    assert em.arm_energy_uj(50.0) == pytest.approx(0.4 * 50.0 + 4.0)
    # 1 W x 1 us = 1 uJ; spin pins the DVFS-scaled frequency
    assert float(em.active_energy_uj(10.0)) == pytest.approx(80.0)
    assert float(em.active_energy_uj(10.0, spin=True)) == pytest.approx(120.0)
    # states normalize shallow->deep regardless of declaration order
    em2 = EnergyModel(sleep_states=((0.1, 20.0, 300.0), (1.0, 0.5, 0.0)))
    assert em2.sleep_states[0][2] == 0.0
    assert em2.params()[2][0] == (1.0, 0.5, 0.0)
    # a model with no zero-residency shallow state is rejected
    with pytest.raises(ValueError, match="shallow"):
        EnergyModel(sleep_states=((0.5, 1.0, 10.0),))


def test_energy_arm_cost_matches_model_on_a_grid_of_targets():
    """The kernels' traced jnp.where chain and the python reference
    must be the same function."""
    from repro.runtime.batched import energy_arm_cost
    em = DEEP_CSTATE_ENERGY_MODEL
    for tgt in (0.5, 5.0, 39.9, 40.0, 120.0, 399.0, 400.0, 5000.0):
        got = float(energy_arm_cost(np.float32(tgt), em.sleep_states))
        assert got == pytest.approx(em.arm_energy_uj(tgt), rel=1e-6)


# ------------------------------------------- batched kernels: identity

@pytest.mark.parametrize("stepping", STEPPINGS)
@pytest.mark.parametrize("em", (DEFAULT_ENERGY_MODEL,
                                DEEP_CSTATE_ENERGY_MODEL),
                         ids=("default", "deep"))
def test_kernel_energy_obeys_conservation_identity(stepping, em):
    grid = SweepGrid.of_points(_mixed_points())
    cfg = SimRunConfig(duration_us=30_000.0, sleep_model=HR_SLEEP_MODEL,
                       window_us=1_000.0, energy_model=em)
    bs = simulate_batch(grid, cfg, slot_us=0.5, stepping=stepping)
    _check_conservation(bs, em)


def test_energy_components_isolate():
    pts = [dict(t_s_us=20.0, t_l_us=200.0, m=2, n_queues=1,
                rate_mpps=8.0, seed=0)]
    grid = SweepGrid.of_points(pts)
    base = dict(duration_us=20_000.0, sleep_model=HR_SLEEP_MODEL)
    # active-only model: total energy IS total awake time (1 W)
    em_a = EnergyModel(active_power_w=1.0, sleep_states=((0.0, 0.0, 0.0),))
    bs = simulate_batch(grid, SimRunConfig(energy_model=em_a, **base),
                        slot_us=0.5)
    assert float(bs.energy_uj[0]) == pytest.approx(float(bs.awake_us[0]),
                                                   rel=CONS_REL)
    # sleep-only model: total energy counts the armed sleeps alone
    em_s = EnergyModel(active_power_w=0.0, sleep_states=((0.5, 2.0, 0.0),))
    bs = simulate_batch(grid, SimRunConfig(energy_model=em_s, **base),
                        slot_us=0.5)
    want = (float(bs.ts_arms[0]) * (0.5 * 20.0 + 2.0)
            + float(bs.busy_tries[0]) * (0.5 * 200.0 + 2.0))
    assert float(bs.energy_uj[0]) == pytest.approx(want, rel=CONS_REL)


# -------------------------------------------------- event engine + spill

def test_event_engine_energy_windows_and_spill_conserve():
    p = dict(t_s_us=25.0, t_l_us=300.0, m=2, n_queues=1,
             rate_mpps=0.5 * 29.76, seed=7)
    cfg = SimRunConfig(duration_us=30_000.0, sleep_model=HR_SLEEP_MODEL,
                       window_us=1_000.0, seed=7,
                       energy_model=DEEP_CSTATE_ENERGY_MODEL)
    rs = _event_run(p, cfg)
    w = rs.windows
    assert rs.energy_uj > 0.0
    assert w.energy_uj.sum() + w.spill_energy_uj \
        == pytest.approx(rs.energy_uj, rel=1e-9)
    assert rs.energy_per_packet_nj \
        == pytest.approx(1e3 * rs.energy_uj / rs.items)
    assert rs.summary()["energy_uj"] == pytest.approx(rs.energy_uj)


def test_spin_energy_pins_dvfs_scaled_active_power():
    em = DEEP_CSTATE_ENERGY_MODEL
    cfg = SimRunConfig(duration_us=20_000.0, seed=3, energy_model=em)
    rs = simulate_run(BusyPollPolicy(), PoissonWorkload(5.0), cfg)
    # a spinning core never arms a timer: flat dvfs-scaled active power
    assert rs.energy_uj == pytest.approx(
        em.active_power_w * em.dvfs_busy_scale * rs.awake_ns / 1e3,
        rel=1e-6)


def test_window_accum_spills_post_duration_events():
    """Regression (the _idx clamp): contributions at t >= duration —
    the event engine's final-drain pass — must land in the spill
    scalars, never the last window."""
    cfg = SimRunConfig(duration_us=100.0, window_us=10.0)
    wa = WindowAccum(cfg)
    wa.add(5.0, offered=1.0, served=1.0, lat_area=2.0, awake=0.5,
           energy_uj=3.0)
    wa.add(99.9, served=2.0)
    wa.add(100.0, served=7.0, lat_area=4.0, awake=0.2, energy_uj=5.0)
    wa.add(250.0, offered=1.0)
    s = wa.series(cfg)
    assert s.served[0] == 1.0 and s.served[-1] == 2.0
    assert s.served.sum() == 3.0
    assert s.spill_served == 7.0 and s.spill_offered == 1.0
    assert s.spill_energy_uj == 5.0 and s.spill_lat_area_us == 4.0
    # post-duration controller/latency samples are skipped, not clamped
    wa.control(100.0, 0.5, 20.0)
    wa.latency_samples(101.0, [9.0])
    assert wa.rho_cnt[-1] == 0 and not wa.samples[-1]


def test_final_drain_last_window_parity_cross_engine():
    """With the drain spilled, the event engine's LAST window is a
    normal window and agrees with the batched kernel's (which never
    runs past duration) like any other window does."""
    p = dict(t_s_us=100.0, t_l_us=1_000.0, m=1, n_queues=1,
             rate_mpps=0.95 * 29.76, seed=0)
    cfg = SimRunConfig(duration_us=30_000.0, sleep_model=HR_SLEEP_MODEL,
                       window_us=1_000.0, seed=0)
    rs = _event_run(p, cfg)
    w = rs.windows
    # the drain is real at this load: the final busy period crosses the
    # run end and its serves land past duration — about half a window's
    # worth, which the old clamp would have dumped into the last bin
    assert w.spill_served > 5_000.0
    assert w.served.sum() + w.spill_served == pytest.approx(rs.items)
    assert w.energy_uj.sum() + w.spill_energy_uj \
        == pytest.approx(rs.energy_uj, rel=1e-9)
    wb = simulate_batch(SweepGrid.of_points([p]), cfg,
                        slot_us=0.5).windows(0)
    a, b = w.served[-1], float(wb.served[-1])
    assert abs(a - b) <= 0.25 * max(a, b) + 500.0, (a, b)


# ----------------------------------------------------- merge / rollups

def test_run_stats_merge_and_merge_all_conserve_energy():
    p = dict(t_s_us=20.0, t_l_us=300.0, m=2, n_queues=1,
             rate_mpps=8.0, seed=0)
    cfg = SimRunConfig(duration_us=20_000.0, window_us=2_000.0,
                       sleep_model=HR_SLEEP_MODEL)
    runs = [_event_run(p, replace(cfg, seed=s)) for s in (1, 2, 3)]
    singles = [r.energy_uj for r in runs]
    assert all(e > 0.0 for e in singles)
    merged = runs[0].merge(runs[1])
    assert merged.energy_uj == pytest.approx(singles[0] + singles[1])
    runs = [_event_run(p, replace(cfg, seed=s)) for s in (1, 2, 3)]
    rolled = runs[0].merge_all(runs[1:])
    assert rolled.energy_uj == pytest.approx(sum(singles))
    # windowed energy merged per bin and still sums (with spill) to total
    w = rolled.windows
    assert w.energy_uj.sum() + w.spill_energy_uj \
        == pytest.approx(rolled.energy_uj, rel=1e-9)


def test_fleet_energy_per_host_identity_and_cluster_rollup():
    from repro.runtime.fleet import FleetGrid, simulate_fleet
    from repro.runtime.simcore import FleetConfig

    em = DEEP_CSTATE_ENERGY_MODEL
    cfg = SimRunConfig(duration_us=20_000.0, sleep_model=HR_SLEEP_MODEL,
                       energy_model=em)
    fg = FleetGrid.product(fleet=FleetConfig(n_hosts=3),
                           t_s_us=(25.0,), t_l_us=(300.0,),
                           rate_mpps=(0.4 * 29.76 * 3,),
                           m=(2,), n_queues=(1,), seeds=(0,))
    arm_s, arm_l = em.arm_energy_uj(25.0), em.arm_energy_uj(300.0)
    for st in STEPPINGS:
        fs = simulate_fleet(fg, cfg, slot_us=0.5, shard=False, stepping=st)
        pred = (em.active_power_w * fs.awake_us
                + fs.ts_arms * arm_s + fs.busy_tries * arm_l)
        np.testing.assert_allclose(fs.energy_uj, pred, rtol=CONS_REL,
                                   atol=1.0)
        assert float(fs.total_energy_uj[0]) \
            == pytest.approx(float(fs.energy_uj[0].sum()), rel=1e-6)
        assert np.all(fs.host_power_w > 0.0)
        assert float(fs.energy_per_packet_nj[0]) > 0.0
        # cluster rollup through RunStats.merge_all conserves energy
        hosts = fs.host_run_stats(0)
        rolled = hosts[0].merge_all(hosts[1:])
        assert rolled.energy_uj == pytest.approx(
            float(fs.total_energy_uj[0]), rel=1e-6, abs=1.0)


# ------------------------------------------------- cross-engine parity

def test_energy_parity_event_vs_both_kernels():
    pts = _band_points()
    cfg = SimRunConfig(duration_us=120_000.0, sleep_model=HR_SLEEP_MODEL)
    ev = [_event_run(p, replace(cfg, seed=p["seed"])) for p in pts]
    grid = SweepGrid.of_points(pts)
    for st in STEPPINGS:
        bs = simulate_batch(grid, cfg, slot_us=0.5, stepping=st)
        for i, rs in enumerate(ev):
            e_ev, e_bs = rs.energy_uj, float(bs.energy_uj[i])
            assert abs(e_bs - e_ev) <= E_ABS_UJ + E_REL * e_ev, \
                (st, i, e_bs, e_ev)
            pp_ev = rs.energy_per_packet_nj
            pp_bs = float(bs.energy_per_packet_nj[i])
            assert abs(pp_bs - pp_ev) <= EPP_ABS_NJ + EPP_REL * pp_ev, \
                (st, i, pp_bs, pp_ev)


# --------------------------------------------------- hr_sleep calibrate

def test_calibrate_margin_floored_at_spin_resolution():
    cal = calibrate(samples=25, probe_ns=1_000)
    # the margin the spin tail must cover can never be finer than what
    # the spin loop can resolve, nor below the 1us bulk/spin split floor
    assert cal.margin_ns >= cal.spin_resolution_ns
    assert cal.margin_ns >= 1_000
    assert cal.spin_resolution_ns >= 1
    # min_sleep_ns is the mean ACHIEVED duration of a probe_ns request:
    # at least the request itself (sleeps never return early)
    assert cal.min_sleep_ns >= 1_000


# ------------------------------------------- hypothesis (optional)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    point_st = st.fixed_dictionaries(dict(
        t_s_us=st.floats(min_value=4.0, max_value=60.0,
                         allow_nan=False, allow_infinity=False),
        t_l_us=st.floats(min_value=80.0, max_value=1000.0,
                         allow_nan=False, allow_infinity=False),
        m=st.integers(min_value=1, max_value=4),
        n_queues=st.integers(min_value=1, max_value=3),
        rate_mpps=st.floats(min_value=0.5, max_value=24.0,
                            allow_nan=False, allow_infinity=False),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    ))

    @settings(max_examples=10, deadline=None)
    @given(pts=st.lists(point_st, min_size=1, max_size=4),
           stepping=st.sampled_from(STEPPINGS),
           deep=st.booleans())
    def test_energy_identity_holds_for_random_grids(pts, stepping, deep):
        em = DEEP_CSTATE_ENERGY_MODEL if deep else DEFAULT_ENERGY_MODEL
        cfg = SimRunConfig(duration_us=20_000.0,
                           sleep_model=HR_SLEEP_MODEL,
                           window_us=1_000.0, energy_model=em)
        bs = simulate_batch(SweepGrid.of_points(pts), cfg, slot_us=0.5,
                            stepping=stepping)
        _check_conservation(bs, em)
