"""Tests for hr_sleep, trylock, controller, and the real-thread pollers."""

import threading
import time

import numpy as np
import pytest

from repro.core import (
    BoundedQueue,
    BusyPollLoop,
    MetronomeConfig,
    MetronomeController,
    MetronomePollers,
    TryLock,
    hr_sleep,
    measure_precision,
    naive_sleep,
)


# ---------------------------------------------------------------------------
# hr_sleep
# ---------------------------------------------------------------------------

def test_hr_sleep_never_undershoots():
    for tgt in (5_000, 50_000, 200_000):
        t0 = time.perf_counter_ns()
        hr_sleep(tgt)
        assert time.perf_counter_ns() - t0 >= tgt


def test_hr_sleep_more_precise_than_naive():
    """Table 1 structure: mean overshoot of hr_sleep < naive at us scale."""
    targets = [20_000, 100_000]
    hr = measure_precision(hr_sleep, targets, samples=60)
    nv = measure_precision(naive_sleep, targets, samples=60)
    for t in targets:
        hr_over = hr[t][0] - t
        nv_over = nv[t][0] - t
        assert hr_over < nv_over, (t, hr_over, nv_over)


def test_hr_sleep_sub_us_immediate():
    t0 = time.perf_counter_ns()
    hr_sleep(500, sub_us_immediate=True)
    assert time.perf_counter_ns() - t0 < 1_000_000  # returned ~immediately


# ---------------------------------------------------------------------------
# trylock
# ---------------------------------------------------------------------------

def test_trylock_single_winner():
    lock = TryLock()
    winners = []
    barrier = threading.Barrier(8)

    def race(i):
        barrier.wait()
        if lock.try_acquire():
            winners.append(i)

    ts = [threading.Thread(target=race, args=(i,)) for i in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert len(winners) == 1
    assert lock.acquisitions == 1
    assert lock.busy_tries == 7
    lock.release()
    assert lock.try_acquire()


# ---------------------------------------------------------------------------
# controller
# ---------------------------------------------------------------------------

def test_controller_converges_and_respects_roles():
    cfg = MetronomeConfig(m=3, v_target_us=10.0, t_long_us=500.0, alpha=0.2)
    ctrl = MetronomeController(cfg)
    for _ in range(200):
        ctrl.on_cycle_end(busy_us=30.0, vacation_us=10.0)   # rho -> 0.75
    assert ctrl.rho == pytest.approx(0.75, abs=0.01)
    ts = ctrl.timeout_us(primary=True)
    expected = 3 * 10.0 * (1 - 0.75) / (1 - 0.75**3)
    assert ts == pytest.approx(expected, rel=0.02)
    assert ctrl.timeout_us(primary=False) == 500.0
    assert ctrl.timeout_ns(primary=False) == 500_000


def test_controller_clamps():
    cfg = MetronomeConfig(m=4, v_target_us=10.0, ts_min_us=2.0)
    ctrl = MetronomeController(cfg)
    for _ in range(100):
        ctrl.on_cycle_end(busy_us=1000.0, vacation_us=0.001)  # rho -> 1
    assert ctrl.t_short_us >= 2.0
    for _ in range(300):
        ctrl.on_cycle_end(busy_us=0.0, vacation_us=100.0)     # rho -> 0
    assert ctrl.t_short_us <= 4 * 10.0 + 1e-9


# ---------------------------------------------------------------------------
# pollers (integration, real threads)
# ---------------------------------------------------------------------------

def _feed(q: BoundedQueue, n: int, rate_hz: float):
    period = 1.0 / rate_hz
    for i in range(n):
        q.push(i)
        time.sleep(period)


def test_metronome_pollers_drain_everything():
    q = BoundedQueue(capacity=4096)
    seen = []
    pollers = MetronomePollers([q], process=seen.extend,
                               cfg=MetronomeConfig(m=3, v_target_us=200.0,
                                                   t_long_us=2000.0))
    pollers.start()
    _feed(q, 300, rate_hz=3000.0)
    time.sleep(0.2)
    stats = pollers.stop()
    assert len(seen) == 300
    assert sorted(seen) == list(range(300))          # no loss, no duplication
    assert stats.cycles > 0
    assert q.dropped == 0
    assert stats.cpu_fraction < 1.0                  # it actually slept


def test_metronome_cpu_below_busy_poll():
    def run(cls, **kw):
        q = BoundedQueue(capacity=4096)
        sink = []
        loop = cls([q], process=sink.extend, **kw)
        loop.start()
        _feed(q, 200, rate_hz=2000.0)
        deadline = time.monotonic() + 3.0
        while len(sink) < 200 and time.monotonic() < deadline:
            time.sleep(0.01)                 # let the pollers drain the tail
        st = loop.stop()
        return st, len(sink)

    m_stats, m_n = run(MetronomePollers,
                       cfg=MetronomeConfig(m=2, v_target_us=500.0, t_long_us=5000.0))
    b_stats, b_n = run(BusyPollLoop)
    assert m_n == b_n == 200
    assert m_stats.cpu_fraction < 0.8 * b_stats.cpu_fraction


def test_bounded_queue_drops_on_overflow():
    q = BoundedQueue(capacity=8)
    for i in range(20):
        q.push(i)
    assert len(q) == 8
    assert q.dropped == 12
    assert q.offered == 20


def test_pollers_latency_bounded_by_vacation_target():
    q = BoundedQueue(capacity=4096)
    pollers = MetronomePollers([q], process=lambda b: None,
                               cfg=MetronomeConfig(m=3, v_target_us=300.0,
                                                   t_long_us=3000.0),
                               latency_sample_every=1)
    pollers.start()
    _feed(q, 150, rate_hz=1500.0)
    time.sleep(0.1)
    stats = pollers.stop()
    assert stats.latency_samples_us, "no latency samples collected"
    med = float(np.median(stats.latency_samples_us))
    # Retrieval latency should be on the order of the vacation target, far
    # below the backup timeout (which would indicate a dead primary).
    assert med < 3000.0, med
