"""Property tests for the closed-form renewal analytics (paper Eqs 1-13)."""

import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import analytics as an

finite = dict(allow_nan=False, allow_infinity=False)
ts_st = st.floats(min_value=1.0, max_value=100.0, **finite)
ratio_st = st.floats(min_value=1.5, max_value=100.0, **finite)  # T_L / T_S
m_st = st.integers(min_value=2, max_value=8)
rho_st = st.floats(min_value=0.0, max_value=0.999, **finite)


def test_busy_period_fixed_point():
    # Eq (3) solves Eq (2): B = rho*(V + B)
    v, rho = 20.0, 0.7
    b = an.busy_period_mean(v, rho)
    assert np.isclose(b, rho * (v + b))


@given(ts=ts_st, ratio=ratio_st, m=m_st)
@settings(max_examples=200, deadline=None)
def test_cdf_is_distribution(ts, ratio, m):
    tl = ts * ratio
    xs = np.linspace(0, ts * 1.2, 64)
    cdf = an.vacation_cdf_high(xs, ts, tl, m)
    assert np.all(cdf >= -1e-12) and np.all(cdf <= 1 + 1e-12)
    assert np.all(np.diff(cdf) >= -1e-9)          # monotone
    assert cdf[-1] == pytest.approx(1.0)          # atom at T_S closes it


@given(ts=ts_st, ratio=ratio_st, m=m_st)
@settings(max_examples=100, deadline=None)
def test_mean_vacation_matches_cdf_integral(ts, ratio, m):
    # Eq (6) == integral of the survival function of Eq (5)
    tl = ts * ratio
    xs = np.linspace(0, ts, 20001)
    numeric = np.trapezoid(1.0 - an.vacation_cdf_high(xs, ts, tl, m), xs)
    assert an.mean_vacation_high(ts, tl, m) == pytest.approx(numeric, rel=1e-3)


@given(ts=ts_st, ratio=ratio_st, m=m_st)
@settings(max_examples=100, deadline=None)
def test_pdf_integrates_to_cdf_mass(ts, ratio, m):
    # Eq (9) is the density of Eq (5) below T_S (rest is the atom at T_S).
    tl = ts * ratio
    xs = np.linspace(0, ts, 20001)
    mass = np.trapezoid(an.vacation_pdf_high(xs, ts, tl, m), xs)
    assert mass == pytest.approx(an.vacation_cdf_high(ts - 1e-9, ts, tl, m), rel=1e-3)


@given(ts=ts_st, ratio=ratio_st, m=m_st)
@settings(max_examples=100, deadline=None)
def test_backup_success_prob_is_integral(ts, ratio, m):
    # Our corrected Eq (7) must equal its defining integral.
    tl = ts * ratio
    xs = np.linspace(0, ts, 20001)
    numeric = np.trapezoid((1 / tl) * (1 - xs / tl) ** (m - 2), xs)
    assert an.backup_success_prob(ts, tl, m) == pytest.approx(numeric, rel=1e-3)
    assert 0.0 < an.backup_success_prob(ts, tl, m) < 1.0


@given(ts=ts_st, m=m_st)
@settings(max_examples=50, deadline=None)
def test_low_load_limit(ts, m):
    """Low-load regime consistency.

    Integrating Eq (8) (min of M uniforms) gives exactly T_S/(M+1); the
    paper's stated low-load mean T_S/M instead comes from the App C general
    form at p=1 (M-1 uniforms + the finishing primary's atom at T_S).  We
    pin down both facts — the adaptation rule (Eq 11/12) uses T_S/M.
    """
    xs = np.linspace(0, ts, 20001)
    numeric = np.trapezoid(1.0 - an.vacation_cdf_low(xs, ts, m), xs)
    assert numeric == pytest.approx(ts / (m + 1), rel=1e-3)
    assert an.mean_vacation_general(ts, ts * 50, m, p=1.0) == pytest.approx(ts / m, rel=1e-6)
    assert an.mean_vacation_low(ts, m) == pytest.approx(ts / m)


@given(ts=ts_st, ratio=ratio_st, m=m_st)
@settings(max_examples=100, deadline=None)
def test_general_form_limits(ts, ratio, m):
    """App C exact form must recover Eq (6) at p->0 and T_S/M at p->1.

    This is the test that exposes the paper's printed-denominator typo
    (documented in analytics.mean_vacation_general).
    """
    tl = ts * ratio
    assert an.mean_vacation_general(ts, tl, m, p=1e-12) == pytest.approx(
        an.mean_vacation_high(ts, tl, m), rel=1e-6)
    assert an.mean_vacation_general(ts, tl, m, p=1.0) == pytest.approx(ts / m, rel=1e-6)


@given(ts=ts_st, m=m_st, p=st.floats(min_value=1e-6, max_value=1.0, **finite))
@settings(max_examples=100, deadline=None)
def test_eq13_approx_converges_to_exact(ts, m, p):
    # For T_L >> T_S the exact App C form converges to Eq (13).
    tl = ts * 1e5
    exact = an.mean_vacation_general(ts, tl, m, p)
    approx = an.mean_vacation_general_approx(ts, m, p)
    assert exact == pytest.approx(approx, rel=1e-3)


@given(v=ts_st, m=m_st, rho=rho_st)
@settings(max_examples=200, deadline=None)
def test_adaptive_ts_inverts_eq13(v, m, rho):
    """Eq (12) is exactly the T_S with which Eq (13) yields E[V] = V-bar."""
    ts = float(an.adaptive_ts(v, rho, m, ts_min=0.0))
    ev = an.mean_vacation_general_approx(ts, m, p=1.0 - rho)
    assert ev == pytest.approx(v, rel=1e-6)


def test_adaptive_ts_limits():
    v, m = 10.0, 3
    assert an.adaptive_ts(v, 0.0, m, ts_min=0) == pytest.approx(m * v)   # low load
    assert an.adaptive_ts(v, 1.0, m, ts_min=0) == pytest.approx(v)       # high load
    # monotone decreasing in rho
    rhos = np.linspace(0, 1, 33)
    ts = np.array([an.adaptive_ts(v, r, m, ts_min=0) for r in rhos])
    assert np.all(np.diff(ts) <= 1e-12)


@given(rho0=rho_st, b=ts_st, v=ts_st,
       alpha=st.floats(min_value=0.01, max_value=1.0, **finite))
@settings(max_examples=100, deadline=None)
def test_ewma_rho_bounded(rho0, b, v, alpha):
    r = an.ewma_rho(rho0, b, v, alpha)
    assert 0.0 <= r <= 1.0


def test_ewma_converges_to_true_load():
    rho = 0.5
    for _ in range(300):
        rho = float(an.ewma_rho(rho, b=30.0, v=10.0, alpha=0.125))
    assert rho == pytest.approx(0.75, abs=1e-6)   # B/(V+B) = 30/40


# ---------------------------------------------------------------------------
# calibration-layer properties (batched-sweep cross-validation surface)
# ---------------------------------------------------------------------------

@given(v=ts_st, m=m_st,
       ts_min=st.floats(min_value=0.0, max_value=5.0, **finite),
       span=st.floats(min_value=1.0, max_value=500.0, **finite))
@settings(max_examples=200, deadline=None)
def test_adaptive_ts_monotone_in_rho_and_clamped(v, m, ts_min, span):
    """Eq (12) is nonincreasing in rho and always lands inside the
    [ts_min, ts_max] clamp band — for ANY band, including ones tighter
    than the unclamped range."""
    ts_max = ts_min + span
    rhos = np.linspace(0.0, 1.0, 65)
    ts = an.adaptive_ts(v, rhos, m, ts_min=ts_min, ts_max=ts_max)
    assert np.all(np.diff(ts) <= 1e-9)
    assert np.all(ts >= ts_min - 1e-12)
    assert np.all(ts <= ts_max + 1e-12)


def test_adaptive_ts_vectorizes_over_m():
    """Array-valued M (the batched sweep axis) must agree with the
    scalar geometric-series evaluation elementwise."""
    ms = np.array([1, 2, 3, 5, 8])
    rho = 0.62
    vec = an.adaptive_ts(10.0, rho, ms, ts_min=0.0)
    for i, m in enumerate(ms):
        scalar = m * 10.0 / sum(rho**k for k in range(int(m)))
        assert vec[i] == pytest.approx(scalar, rel=1e-12)
    # broadcasting rho x m grids (the calibration lattice shape)
    grid = an.adaptive_ts(10.0, np.linspace(0, 1, 7)[:, None],
                          ms[None, :], ts_min=0.0)
    assert grid.shape == (7, 5)


@given(ts=ts_st, ratio=ratio_st, m=m_st)
@settings(max_examples=100, deadline=None)
def test_general_form_exact_at_endpoints(ts, ratio, m):
    """p=0 (pure high load) and p=1 (pure low load) are *exact* — not
    just limiting — evaluations of the App C form."""
    tl = ts * ratio
    assert an.mean_vacation_general(ts, tl, m, p=0.0) == pytest.approx(
        an.mean_vacation_high(ts, tl, m), rel=1e-12)
    assert an.mean_vacation_general(ts, tl, m, p=1.0) == pytest.approx(
        an.mean_vacation_low(ts, m), rel=1e-12)


@given(ts=ts_st, ratio=ratio_st, m=m_st)
@settings(max_examples=100, deadline=None)
def test_second_moment_vacation_matches_integral(ts, ratio, m):
    """E[V^2] closed form == 2 int x (1 - F(x)) dx for Eq (5)'s V."""
    tl = ts * ratio
    xs = np.linspace(0, ts, 20001)
    surv = (1.0 - np.clip(xs / tl, 0.0, 1.0)) ** (m - 1)
    numeric = np.trapezoid(2.0 * xs * surv, xs)
    assert an.second_moment_vacation_high(ts, tl, m) == pytest.approx(
        numeric, rel=1e-3)


@given(ts=ts_st, ratio=ratio_st, m=m_st)
@settings(max_examples=100, deadline=None)
def test_mean_sojourn_high_bounds(ts, ratio, m):
    """E[V^2]/(2E[V]) lies in [E[V]/2, T_S/2]: Jensen from below, the
    V <= T_S support bound from above (equality when V is
    deterministic, i.e. M=1)."""
    tl = ts * ratio
    w = float(an.mean_sojourn_high(ts, tl, m))
    ev = float(an.mean_vacation_high(ts, tl, m))
    assert ev / 2 - 1e-9 <= w <= ts / 2 + 1e-9
    assert float(an.mean_sojourn_high(ts, tl, 1)) == pytest.approx(ts / 2)
