"""Training substrate: optimizer, data determinism, checkpoint/restart
fault tolerance, elastic reshard, gradient compression."""

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.train import (
    OptConfig,
    TokenDataset,
    apply_updates,
    dequantize_int8,
    init_opt,
    latest_step,
    quantize_int8,
    restore_checkpoint,
    save_checkpoint,
    train_loop,
)

TINY = dataclasses.replace(
    get_config("granite-3-8b").reduced(), n_layers=2, d_model=32,
    n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=211)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_matches_reference_formula():
    params = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    grads = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    cfg = OptConfig(lr=0.1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                    grad_clip=1e9)
    state = init_opt(params, cfg)
    new_p, state, gnorm = apply_updates(params, grads, state, cfg)
    g = np.array([0.1, 0.2, -0.3])
    m = 0.1 * g
    v = 0.001 * g * g
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.999)
    expect = np.array([1.0, -2.0, 3.0]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), expect, rtol=1e-5)
    assert float(gnorm) == pytest.approx(np.linalg.norm(g), rel=1e-5)


def test_adamw_grad_clipping():
    params = {"w": jnp.ones(4)}
    grads = {"w": jnp.full(4, 100.0)}
    cfg = OptConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    state = init_opt(params, cfg)
    p1, _, gnorm = apply_updates(params, grads, state, cfg)
    assert float(gnorm) == pytest.approx(200.0)
    # clipped: effective g = g/200 -> first-step update = lr * 1 (sign)
    assert np.all(np.isfinite(np.asarray(p1["w"])))


def test_moment_dtype_bf16():
    params = {"w": jnp.ones((8, 8), jnp.bfloat16)}
    cfg = OptConfig(moment_dtype="bfloat16")
    state = init_opt(params, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    _, state, _ = apply_updates(params, {"w": jnp.ones((8, 8))}, state, cfg)
    assert state["v"]["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_dataset_deterministic_and_seekable():
    ds = TokenDataset(vocab_size=101, seq_len=16, global_batch=4, seed=3)
    b1 = ds.batch(7)
    b2 = ds.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(ds.batch(8)["tokens"], b1["tokens"])
    # next-token labels
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_prefetcher_delivers_in_order_and_seeks():
    from repro.train import HostPrefetcher
    ds = TokenDataset(vocab_size=101, seq_len=8, global_batch=2, seed=1)
    pf = HostPrefetcher(ds, start_step=5, depth=3)
    try:
        for step in (5, 6, 7):
            got = pf.get(step)
            np.testing.assert_array_equal(got["tokens"], ds.batch(step)["tokens"])
        got = pf.get(42)   # elastic seek
        np.testing.assert_array_equal(got["tokens"], ds.batch(42)["tokens"])
    finally:
        pf.stop()


# ---------------------------------------------------------------------------
# checkpoint + fault tolerance
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    save_checkpoint(str(tmp_path), 5, tree)
    assert latest_step(str(tmp_path)) == 5
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    restored, meta = restore_checkpoint(str(tmp_path), 5, like)
    assert meta["step"] == 5
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_detects_tree_mismatch(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"a": jnp.ones(3)})
    with pytest.raises(ValueError, match="mismatch"):
        restore_checkpoint(str(tmp_path), 1, {"zz": jnp.ones(3)})


def test_train_restart_reproduces_uninterrupted_run(tmp_path):
    """The fault-tolerance contract: crash at step 7, restart, and the
    final loss trajectory equals an uninterrupted run (deterministic
    data + checkpoint/restore)."""
    steps, save_every = 10, 2

    ref = train_loop(TINY, steps=steps, ckpt_dir=str(tmp_path / "ref"),
                     save_every=save_every, global_batch=2, seq_len=16)

    class Boom(RuntimeError):
        pass

    def injector(step):
        if step == 7 and not os.path.exists(tmp_path / "crashed"):
            (tmp_path / "crashed").touch()
            raise Boom("simulated preemption")

    with pytest.raises(Boom):
        train_loop(TINY, steps=steps, ckpt_dir=str(tmp_path / "ft"),
                   save_every=save_every, global_batch=2, seq_len=16,
                   failure_injector=injector)
    # restart: resumes from step 6 checkpoint and finishes
    res = train_loop(TINY, steps=steps, ckpt_dir=str(tmp_path / "ft"),
                     save_every=save_every, global_batch=2, seq_len=16,
                     failure_injector=injector)
    assert res["resumed_from"] == 6
    np.testing.assert_allclose(res["losses"], ref["losses"][6:], rtol=1e-5)


def test_loss_decreases_over_short_run(tmp_path):
    res = train_loop(TINY, steps=12, ckpt_dir=str(tmp_path), save_every=50,
                     global_batch=2, seq_len=16,
                     opt_cfg=OptConfig(lr=3e-3))
    assert res["losses"][-1] < res["losses"][0]


# ---------------------------------------------------------------------------
# elastic reshard + compression (multi-device: subprocess with 8 host devs)
# ---------------------------------------------------------------------------

SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.train import restore_checkpoint, make_dp_grad_fn

    ckpt = %r
    # --- elastic restore onto an 8-device mesh (written on 1 device) ---
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    like = {"a": jax.ShapeDtypeStruct((8, 6), jnp.float32)}
    shardings = {"a": NamedSharding(mesh, P("data", "model"))}
    tree, meta = restore_checkpoint(ckpt, 3, like, shardings=shardings)
    assert tree["a"].sharding == shardings["a"], tree["a"].sharding
    np.testing.assert_array_equal(
        np.asarray(tree["a"]), np.arange(48, dtype=np.float32).reshape(8, 6))

    # --- compressed DP gradients: int8 on the wire, close to exact ---
    def loss(params, batch):
        return jnp.mean((batch @ params["w"]) ** 2)

    params = {"w": jnp.asarray(np.random.RandomState(0).randn(6, 1),
                               jnp.float32)}
    batch = jnp.asarray(np.random.RandomState(1).randn(32, 6), jnp.float32)
    gfn_c = make_dp_grad_fn(loss, mesh, compress=True)
    gfn_e = make_dp_grad_fn(loss, mesh, compress=False)
    gc = gfn_c(params, batch)["w"]
    ge = gfn_e(params, batch)["w"]
    rel = float(jnp.linalg.norm(gc - ge) / jnp.linalg.norm(ge))
    assert rel < 0.02, rel
    txt = jax.jit(gfn_c).lower(params, batch).compile().as_text()
    assert "s8[" in txt and "all-gather" in txt, "int8 not on the wire"
    print("SUBPROC_OK", rel)
""")


def test_elastic_reshard_and_compression_subprocess(tmp_path):
    save_checkpoint(str(tmp_path), 3,
                    {"a": jnp.arange(48, dtype=jnp.float32).reshape(8, 6)})
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SUBPROC % str(tmp_path)],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)),
                         timeout=300)
    assert "SUBPROC_OK" in out.stdout, out.stderr[-2000:]


def test_int8_quantization_roundtrip():
    x = jnp.asarray(np.random.RandomState(0).randn(64, 32) * 3.0,
                    jnp.float32)
    q, scale = quantize_int8(x)
    y = dequantize_int8(q, scale)
    rel = float(jnp.linalg.norm(y - x) / jnp.linalg.norm(x))
    assert q.dtype == jnp.int8
    assert rel < 0.01, rel
