"""Train driver: a ~100M-parameter gemma-style model with the full
substrate (deterministic pipeline, AdamW, async checkpointing,
crash-resume).  The paper's kind is serving, so the graded end-to-end
driver is serve_metronome.py; this exists to exercise the training path
at real scale knobs.

  PYTHONPATH=src python examples/train_100m.py --smoke        # CI-sized
  PYTHONPATH=src python examples/train_100m.py --steps 300    # ~100M run
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.train import OptConfig, train_loop


def model_100m():
    # ~102M params: 12L x d512 x ffn2048, vocab 32k (gemma-style GeGLU)
    return dataclasses.replace(
        get_config("gemma-2b"), name="gemma-100m", n_layers=12, d_model=512,
        n_heads=8, n_kv_heads=1, head_dim=64, d_ff=2048, vocab_size=32_000,
        param_dtype="float32", compute_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_train100m")
    args = ap.parse_args()

    if args.smoke:
        cfg = dataclasses.replace(model_100m(), n_layers=2, d_model=64,
                                  n_heads=2, n_kv_heads=1, head_dim=32,
                                  d_ff=128, vocab_size=1024)
        steps, gb, seq = 6, 2, 32
    else:
        cfg, steps, gb, seq = model_100m(), args.steps, 8, 512

    n_params = (cfg.vocab_size * cfg.d_model
                + cfg.n_layers * (cfg.d_model * (cfg.n_heads + 2 * cfg.n_kv_heads)
                                  * cfg.resolved_head_dim
                                  + cfg.n_heads * cfg.resolved_head_dim * cfg.d_model
                                  + 3 * cfg.d_model * cfg.d_ff))
    print(f"model ~{n_params / 1e6:.0f}M params; {steps} steps, "
          f"batch {gb} x seq {seq}; checkpoints -> {args.ckpt}")
    res = train_loop(cfg, steps=steps, ckpt_dir=args.ckpt, save_every=20,
                     global_batch=gb, seq_len=seq, remat=not args.smoke,
                     opt_cfg=OptConfig(lr=1e-3,
                                       moment_dtype=cfg.moment_dtype))
    first, last = res["losses"][0], res["losses"][-1]
    origin = ("resumed from " + str(res["resumed_from"])
              if res["resumed_from"] >= 0 else "fresh run")
    print(f"loss {first:.4f} -> {last:.4f} ({origin})")
    assert last < first, "loss did not improve"


if __name__ == "__main__":
    main()
