"""Quickstart: train a tiny model a few steps, then serve it with the
Metronome retrieval loop — the whole stack in under a minute on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses
import time

import jax

from repro.configs import get_config
from repro.core import MetronomeConfig
from repro.models import Model
from repro.runtime import MetronomePolicy
from repro.serving import EngineConfig, InferenceEngine, Request, Server
from repro.train import OptConfig, train_loop

TINY = dataclasses.replace(
    get_config("granite-3-8b").reduced(), n_layers=2, d_model=32,
    n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=211)


def main():
    print("== 1. train a few steps (deterministic synthetic data) ==")
    res = train_loop(TINY, steps=8, ckpt_dir="/tmp/repro_quickstart",
                     save_every=4, global_batch=2, seq_len=16,
                     opt_cfg=OptConfig(lr=3e-3))
    print(f"losses: {['%.3f' % l for l in res['losses']]}")

    print("== 2. serve it with Metronome sleep&wake retrieval ==")
    model = Model(TINY)
    params = model.init(jax.random.PRNGKey(0), max_seq=64)
    engine = InferenceEngine(model, params,
                             EngineConfig(max_slots=4, max_len=64,
                                          prefill_buckets=(8,)))
    warm = Request(prompt=[1, 2], max_new_tokens=2)
    engine.submit([warm]); engine.pump()          # compile caches

    # the same policy object would run in repro.runtime.simulate_run
    policy = MetronomePolicy(
        MetronomeConfig(m=3, v_target_us=2_000.0, t_long_us=50_000.0))
    server = Server(engine, policy)
    server.start()
    reqs = [Request(prompt=[i + 1, i + 2, i + 3], max_new_tokens=6)
            for i in range(8)]
    for r in reqs:
        server.submit(r)
        time.sleep(0.02)
    for r in reqs:
        assert r.wait(10.0)
    stats = server.stop()
    for r in reqs[:3]:
        print(f"req {r.id}: prompt={r.prompt} -> tokens={r.tokens}")
    print(f"host CPU fraction (sum over {policy.threads} pollers): "
          f"{stats.cpu_fraction:.3f}  (busy-poll baseline would be 1.0)")
    print(f"controller: rho={policy.rho:.3f} T_S={policy.t_short_us:.0f}us")


if __name__ == "__main__":
    main()
