"""End-to-end serving driver (the paper's experiment, serving edition):
a token-generation service under Poisson request load, comparing
retrieval policies through the unified ``repro.runtime`` API — the same
policy objects the simulator executes.

Reports the paper's metrics: host CPU fraction, time-to-first-token,
retrieval latency, completed requests — at several offered rates.

  PYTHONPATH=src python examples/serve_metronome.py [--requests 30]
"""

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import MetronomeConfig
from repro.models import Model
from repro.runtime import BusyPollPolicy, FixedPeriodPolicy, MetronomePolicy
from repro.serving import EngineConfig, InferenceEngine, Request, Server

TINY = dataclasses.replace(
    get_config("gemma-2b").reduced(), n_layers=2, d_model=32,
    n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64, vocab_size=211)


def make_engine():
    model = Model(TINY)
    params = model.init(jax.random.PRNGKey(0), max_seq=64)
    eng = InferenceEngine(model, params,
                          EngineConfig(max_slots=4, max_len=64,
                                       prefill_buckets=(8,)))
    warm = Request(prompt=[1, 2], max_new_tokens=2)
    eng.submit([warm])
    eng.pump()
    return eng


def drive(policy, n_req, rate_hz, rng):
    # servers are constructed fresh per run (their engine holds slot state)
    server = Server(make_engine(), policy)
    server.start()
    reqs = []
    for i in range(n_req):
        r = Request(prompt=[(i % 200) + 1, (i % 200) + 2], max_new_tokens=6)
        server.submit(r)
        reqs.append(r)
        time.sleep(rng.exponential(1.0 / rate_hz))      # Poisson arrivals
    ok = all(r.wait(30.0) for r in reqs)
    st = server.stop()
    ttft = np.median([(r.first_token_ns - r.arrival_ns) / 1e6 for r in reqs])
    return dict(ok=ok, cpu=st.cpu_fraction, ttft_ms=float(ttft),
                retr_us=float(np.median(st.retrieval_lat_us))
                if st.retrieval_lat_us else 0.0,
                busy_tries=st.busy_tries, wakeups=st.wakeups)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=30)
    args = ap.parse_args()

    policies = [
        ("metronome", lambda: MetronomePolicy(
            MetronomeConfig(m=3, v_target_us=3_000.0, t_long_us=60_000.0))),
        ("fixed-3ms", lambda: FixedPeriodPolicy(3_000.0, threads=1)),
        ("busy-poll", lambda: BusyPollPolicy()),
    ]
    print(f"{'rate':>8} {'policy':>10} {'cpu':>7} {'ttft_ms':>9} "
          f"{'retr_us':>9} {'wakeups':>8}")
    for rate in (15.0, 40.0, 80.0):
        for name, make_policy in policies:
            rng = np.random.default_rng(0)
            r = drive(make_policy(), args.requests, rate, rng)
            assert r["ok"]
            print(f"{rate:>8.0f} {name:>10} {r['cpu']:>7.3f} "
                  f"{r['ttft_ms']:>9.2f} {r['retr_us']:>9.0f} "
                  f"{r['wakeups']:>8}")
    print("\nMetronome trades a bounded retrieval delay (~V-bar) for a "
          "large host-CPU saving — the paper's Fig 12, serving edition.")


if __name__ == "__main__":
    main()
