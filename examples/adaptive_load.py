"""Paper Fig 11 on the real-thread stack: ramp the offered request rate up
and down and watch the controller's rho estimate and T_S timeout track it.

  PYTHONPATH=src python examples/adaptive_load.py
"""

import dataclasses
import time

import jax

from repro.configs import get_config
from repro.core import MetronomeConfig
from repro.models import Model
from repro.runtime import MetronomePolicy
from repro.serving import EngineConfig, InferenceEngine, Request, Server

TINY = dataclasses.replace(
    get_config("granite-3-8b").reduced(), n_layers=2, d_model=32,
    n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=211)


def main():
    model = Model(TINY)
    params = model.init(jax.random.PRNGKey(0), max_seq=64)
    engine = InferenceEngine(model, params,
                             EngineConfig(max_slots=4, max_len=64,
                                          prefill_buckets=(8,)))
    warm = Request(prompt=[1, 2], max_new_tokens=2)
    engine.submit([warm]); engine.pump()

    policy = MetronomePolicy(
        MetronomeConfig(m=3, v_target_us=2_000.0, t_long_us=40_000.0))
    server = Server(engine, policy)
    server.start()

    # triangular rate profile: 5 -> 80 -> 5 req/s over ~12 s
    phases = [5, 20, 50, 80, 50, 20, 5]
    print(f"{'rate_hz':>8} {'rho':>7} {'T_S_us':>8} {'cpu_so_far':>11}")
    submitted = []
    for rate in phases:
        t_end = time.time() + 12.0 / len(phases)
        while time.time() < t_end:
            r = Request(prompt=[1, 2, 3], max_new_tokens=4)
            server.submit(r)
            submitted.append(r)
            time.sleep(1.0 / rate)
        elapsed = time.monotonic_ns() - server.stats.started_ns
        cpu = server.stats.awake_ns / max(elapsed, 1)
        print(f"{rate:>8} {policy.rho:>7.3f} "
              f"{policy.t_short_us:>8.1f} {cpu:>11.3f}")
    done = sum(1 for r in submitted if r.wait(20.0))
    stats = server.stop()
    print(f"\ncompleted {done}/{len(submitted)} requests; "
          f"final CPU fraction {stats.cpu_fraction:.3f}")
    print("rho rises into the load peak and falls after it; T_S moves "
          "opposite (Eq 12), exactly like the paper's Fig 11.")


if __name__ == "__main__":
    main()
